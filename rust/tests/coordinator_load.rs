//! Coordinator + service under concurrent load: failure-injection-ish
//! tests of the orchestration layer (ordering, backpressure, metric
//! consistency, many small jobs).

use std::sync::Arc;

use cse::coordinator::queue::BoundedQueue;
use cse::coordinator::service::{Answer, Query};
use cse::coordinator::{Coordinator, EmbedJob, JobError, QueryBatch, SimilarityService};
use cse::embed::Params;
use cse::funcs::SpectralFn;
use cse::linalg::Mat;
use cse::sparse::{gen, graph};
use cse::util::rng::Rng;

#[test]
fn many_sequential_jobs_share_a_coordinator() {
    let mut rng = Rng::new(21);
    let coord = Coordinator::new(3);
    let mut total_matvecs = 0;
    for seed in 0..5 {
        let g = gen::erdos_renyi(&mut rng, 120, 360);
        let na = graph::normalized_adjacency(&g.adj);
        let job = EmbedJob::new(
            Params { d: 16, order: 20, cascade: 1, ..Params::default() },
            SpectralFn::Step { c: 0.5 },
            seed,
        );
        let res = coord.run(&na, &job).unwrap();
        assert_eq!(res.e.cols, 16);
        total_matvecs += res.matvecs;
    }
    // Metrics accumulate across jobs.
    assert_eq!(coord.metrics.snapshot().matvecs, total_matvecs);
}

#[test]
fn narrow_shards_and_many_workers_stress() {
    let mut rng = Rng::new(22);
    let g = gen::sbm_by_degree(&mut rng, 200, 4, 6.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let mut job = EmbedJob::new(
        Params { d: 33, order: 24, cascade: 2, ..Params::default() },
        SpectralFn::Step { c: 0.6 },
        9,
    );
    job.shard_width = 1; // 33 shards, maximal contention
    let res = Coordinator::new(8).run(&na, &job).unwrap();
    assert_eq!(res.shards, 33);
    assert_eq!(res.e.cols, 33);
    assert!(res.e.data.iter().all(|v| v.is_finite()));

    // Must equal the 1-worker result exactly.
    let res1 = Coordinator::new(1).run(&na, &job).unwrap();
    assert_eq!(res.e.data, res1.e.data);
}

#[test]
fn service_survives_concurrent_mixed_batches() {
    let mut rng = Rng::new(23);
    let e = Mat::randn(&mut rng, 300, 12);
    let service = Arc::new(SimilarityService::new(e));

    let mut handles = Vec::new();
    for t in 0..4u64 {
        let service = Arc::clone(&service);
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + t);
            let queries: Vec<Query> = (0..200)
                .map(|q| {
                    if q % 3 == 0 {
                        Query::TopK { i: rng.below(300), k: 5 }
                    } else {
                        Query::Corr { i: rng.below(300), j: rng.below(300) }
                    }
                })
                .collect();
            let answers = QueryBatch::run(&service, &queries, 2);
            // Sanity on every answer.
            for a in &answers {
                match a {
                    Answer::Corr(c) => assert!(c.abs() <= 1.0 + 1e-9),
                    Answer::TopK(v) => {
                        assert_eq!(v.len(), 5);
                        for w in v.windows(2) {
                            assert!(w[0].1 >= w[1].1);
                        }
                    }
                    Answer::Shed => panic!("no shed threshold was configured"),
                }
            }
            answers.len()
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 800);
    assert_eq!(service.metrics.snapshot().queries, 800);
}

#[test]
fn queue_backpressure_bounds_memory() {
    // Slow consumer, fast producer: queue length never exceeds capacity.
    let q: Arc<BoundedQueue<Vec<u8>>> = Arc::new(BoundedQueue::new(4));
    let producer = {
        let q = Arc::clone(&q);
        std::thread::spawn(move || {
            for _ in 0..64 {
                q.push(vec![0u8; 1024]).unwrap();
            }
            q.close();
        })
    };
    let mut seen = 0;
    while let Some(_item) = q.pop() {
        assert!(q.len() <= 4, "queue over capacity");
        seen += 1;
        std::thread::sleep(std::time::Duration::from_micros(200));
    }
    producer.join().unwrap();
    assert_eq!(seen, 64);
}

#[test]
fn job_is_reproducible_across_processes_semantics() {
    // Same seed → identical embedding, different seed → different Ω.
    let mut rng = Rng::new(24);
    let g = gen::erdos_renyi(&mut rng, 150, 500);
    let na = graph::normalized_adjacency(&g.adj);
    let mk = |seed| {
        EmbedJob::new(
            Params { d: 12, order: 16, cascade: 1, ..Params::default() },
            SpectralFn::Step { c: 0.5 },
            seed,
        )
    };
    let coord = Coordinator::new(2);
    let a = coord.run(&na, &mk(1)).unwrap();
    let b = coord.run(&na, &mk(1)).unwrap();
    let c = coord.run(&na, &mk(2)).unwrap();
    assert_eq!(a.e.data, b.e.data);
    assert_ne!(a.e.data, c.e.data);
}

#[test]
fn short_deadline_job_aborts_promptly_and_pool_survives() {
    let mut rng = Rng::new(25);
    let g = gen::sbm_by_degree(&mut rng, 2000, 8, 8.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let mut job = EmbedJob::new(
        Params { d: 32, order: 200, cascade: 2, ..Params::default() },
        SpectralFn::Step { c: 0.6 },
        13,
    );
    job.shard_width = 2;
    job.deadline_ms = Some(1); // far below what order-200 over 16 shards needs
    let coord = Coordinator::new(3);
    let t = std::time::Instant::now();
    let err = coord.run(&na, &job).unwrap_err();
    // Cancellation is cooperative but fine-grained (row blocks, series
    // steps, shard boundaries) — the abort must land promptly, not
    // after the job would have finished anyway.
    assert!(t.elapsed() < std::time::Duration::from_secs(30), "abort took {:?}", t.elapsed());
    match err {
        JobError::DeadlineExceeded { done, total, .. } => {
            assert_eq!(total, 16);
            assert!(done < total, "a 1 ms deadline cannot complete all shards");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    // The coordinator and its pool stay reusable after an abort.
    job.deadline_ms = None;
    job.params.order = 12;
    let res = coord.run(&na, &job).unwrap();
    assert_eq!(res.e.cols, 32);
    assert!(res.e.data.iter().all(|v| v.is_finite()));
}
