//! PJRT integration: load the JAX/Pallas AOT artifacts and verify their
//! numerics against the native Rust implementations. Requires
//! `make artifacts` (tests self-skip with a message otherwise) and the
//! `pjrt` cargo feature (the whole file is compiled out without it —
//! the xla/anyhow closure is not vendored in the offline image).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;
use std::sync::Arc;

use cse::embed::fastembed::apply_series;
use cse::embed::op::{DenseOp, Operator};
use cse::par::ExecPolicy;
use cse::linalg::Mat;
use cse::poly::legendre;
use cse::runtime::ops::{GaussKernelOp, PjrtStepOp};
use cse::runtime::{Artifacts, Runtime};
use cse::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping PJRT test: run `make artifacts` first");
        None
    }
}

fn random_contraction(rng: &mut Rng, n: usize) -> Mat {
    let mut s = Mat::randn(rng, n, n);
    for i in 0..n {
        for j in 0..i {
            let v = (s[(i, j)] + s[(j, i)]) / 2.0;
            s[(i, j)] = v;
            s[(j, i)] = v;
        }
    }
    // Bound the spectrum via the Frobenius norm (cheap, safe).
    let f = s.frob_norm();
    s.scale(0.9 / f);
    s
}

#[test]
fn step_artifact_matches_native_step() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Arc::new(Runtime::cpu().unwrap());
    let arts = Artifacts::load(&dir).unwrap();
    let (n, d) = (arts.tile["n"], arts.tile["d"]);

    let mut rng = Rng::new(11);
    let s = random_contraction(&mut rng, n);
    let op = PjrtStepOp::new(rt, &arts, &s).unwrap();

    let qp = Mat::randn(&mut rng, n, d);
    let qpp = Mat::randn(&mut rng, n, d);
    let (c1, c2) = (1.75, 0.75);
    let got = op.step(&qp, &qpp, c1, c2).unwrap();
    let mut want = s.matmul(&qp);
    want.scale(c1);
    want.axpy(-c2, &qpp);
    let err = got.max_abs_diff(&want);
    assert!(err < 1e-3, "PJRT step vs native: {err}"); // f32 artifact
}

#[test]
fn pjrt_series_matches_native_series() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Arc::new(Runtime::cpu().unwrap());
    let arts = Artifacts::load(&dir).unwrap();
    let (n, d) = (arts.tile["n"], arts.tile["d"]);

    let mut rng = Rng::new(12);
    let s = random_contraction(&mut rng, n);
    let op = PjrtStepOp::new(rt, &arts, &s).unwrap();
    let series = legendre::step_coeffs(12, 0.3);
    let q0 = Mat::randn(&mut rng, n, d);

    let mut mv_pjrt = 0;
    let got = op.apply_series(&series, &q0, &mut mv_pjrt).unwrap();
    let mut mv_native = 0;
    let want = apply_series(&DenseOp(s), &series, &q0, &mut mv_native, &ExecPolicy::serial());
    assert_eq!(mv_pjrt, mv_native);
    let err = got.max_abs_diff(&want);
    // 12 recursion steps in f32 vs f64 accumulate rounding.
    assert!(err < 5e-2, "PJRT series vs native: {err}");
}

#[test]
fn step_op_as_plain_operator() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Arc::new(Runtime::cpu().unwrap());
    let arts = Artifacts::load(&dir).unwrap();
    let (n, d) = (arts.tile["n"], arts.tile["d"]);

    let mut rng = Rng::new(13);
    let s = random_contraction(&mut rng, n);
    let op = PjrtStepOp::new(rt, &arts, &s).unwrap();
    let x = Mat::randn(&mut rng, n, d);
    let got = Operator::apply(&op, &x, &ExecPolicy::serial());
    let want = s.matmul(&x);
    assert!(got.max_abs_diff(&want) < 1e-3);
    assert_eq!(op.dim(), n);
}

#[test]
fn gauss_artifact_matches_dense_kernel() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Arc::new(Runtime::cpu().unwrap());
    let arts = Artifacts::load(&dir).unwrap();
    let info = arts.find_prefix("gauss_matvec").unwrap();
    let (l, feat) = (info.params[0][0], info.params[0][1]);
    let d = info.params[1][1];

    let mut rng = Rng::new(14);
    let pts = Mat::randn(&mut rng, l, feat);
    let alpha = 1.5;
    let op = GaussKernelOp::new(rt, &arts, &pts, alpha).unwrap();

    let q = Mat::randn(&mut rng, l, d);
    let got = Operator::apply(&op, &q, &ExecPolicy::serial());

    // Dense oracle: materialize K.
    let mut k = Mat::zeros(l, l);
    for i in 0..l {
        for j in 0..l {
            let d2: f64 = pts
                .row(i)
                .iter()
                .zip(pts.row(j))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            k[(i, j)] = (-d2 / (2.0 * alpha * alpha)).exp();
        }
    }
    let want = k.matmul(&q);
    let err = got.max_abs_diff(&want);
    assert!(err < 1e-2, "gauss artifact vs dense: {err}");
}

#[test]
fn fused_fastembed_artifact_matches_rust_loop() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = Artifacts::load(&dir).unwrap();
    let info = arts.find_prefix("fastembed_").unwrap();
    let (n, d) = (info.params[0][0], info.params[1][1]);
    let order = info.params[2][0] - 1;

    let mut rng = Rng::new(15);
    let s = random_contraction(&mut rng, n);
    let omega = Mat::randn(&mut rng, n, d);
    let series = legendre::step_coeffs(order, 0.25);

    // Fused L2 artifact (scan baked at FULL_L).
    let exe = rt.load_hlo_text(&info.file).unwrap();
    let coeffs_f32: Vec<f32> = series.coeffs.iter().map(|&x| x as f32).collect();
    let out = rt
        .execute_tuple1(
            &exe,
            &[
                cse::runtime::client::literal_from_mat(&s).unwrap(),
                cse::runtime::client::literal_from_mat(&omega).unwrap(),
                cse::runtime::client::literal_vec(&coeffs_f32),
            ],
        )
        .unwrap();
    let got = cse::runtime::client::mat_from_literal(&out, n, d).unwrap();

    let mut mv = 0;
    let want = apply_series(&DenseOp(s), &series, &omega, &mut mv, &ExecPolicy::serial());
    let err = got.max_abs_diff(&want);
    assert!(err < 5e-2, "fused artifact vs rust loop: {err}");
}

#[test]
fn power_iter_artifact_estimates_norm() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let arts = Artifacts::load(&dir).unwrap();
    let info = arts.find_prefix("power_iter").unwrap();
    let (n, b) = (info.params[0][0], info.params[1][1]);

    let mut rng = Rng::new(16);
    let s = random_contraction(&mut rng, n);
    let v0 = Mat::randn(&mut rng, n, b);
    let exe = rt.load_hlo_text(&info.file).unwrap();
    let outs = rt
        .execute_tuple(
            &exe,
            &[
                cse::runtime::client::literal_from_mat(&s).unwrap(),
                cse::runtime::client::literal_from_mat(&v0).unwrap(),
            ],
        )
        .unwrap();
    let est: Vec<f32> = outs[0].to_vec().unwrap();
    // Native power iteration on the same operator.
    let mut rng2 = Rng::new(17);
    let native = cse::embed::norm::spectral_norm(
        &DenseOp(s),
        &cse::embed::norm::NormEstParams { iters: 50, safety: 1.0, vectors: Some(16) },
        &mut rng2,
        &ExecPolicy::serial(),
    );
    assert!(
        (est[0] as f64 - native).abs() < 0.05 * native.max(0.01),
        "pjrt {} vs native {}",
        est[0],
        native
    );
}
