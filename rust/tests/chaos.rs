//! Chaos tests: deterministic fault injection (`cse::fault`) against
//! the fault-tolerant coordinator. Every test arms a failpoint spec,
//! runs a real embedding job, and requires the recovery to be
//! *bitwise invisible*: the surviving output must equal the fault-free
//! run exactly, because a retried shard re-executes the same pure
//! function of its Ω column slice.
//!
//! The fault registry is process-global, so every test that arms it
//! holds `LOCK` for its whole body and disarms before releasing.

use std::sync::Mutex;

use cse::coordinator::{Coordinator, EmbedJob, JobError, JobResult};
use cse::embed::Params;
use cse::funcs::SpectralFn;
use cse::par::ExecPolicy;
use cse::sparse::{gen, graph, Csr};
use cse::util::rng::Rng;

static LOCK: Mutex<()> = Mutex::new(());

fn test_graph() -> Csr {
    let mut rng = Rng::new(61);
    let g = gen::sbm_by_degree(&mut rng, 600, 6, 7.0, 1.0);
    graph::normalized_adjacency(&g.adj)
}

/// One-column shards → 24 shards → at least 24 deterministic fault
/// draws per run, so a per-shard fault probability is exercised many
/// times whatever the worker interleaving.
fn run_job(
    na: &Csr,
    workers: usize,
    threads: usize,
    max_retries: usize,
) -> Result<JobResult, JobError> {
    let mut job = EmbedJob::new(
        Params {
            d: 24,
            order: 24,
            cascade: 2,
            exec: ExecPolicy::with_threads(threads),
            ..Params::default()
        },
        SpectralFn::Step { c: 0.6 },
        19,
    );
    job.shard_width = 1;
    job.max_retries = max_retries;
    Coordinator::new(workers).run(na, &job)
}

#[test]
fn shard_panics_are_retried_and_bitwise_invisible() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let na = test_graph();
    cse::fault::disarm();
    let clean = run_job(&na, 3, 1, 8).unwrap();
    assert_eq!(clean.retries, 0);

    let before = cse::fault::injected();
    cse::fault::arm("shard_run:panic:p=0.3:seed=7").unwrap();
    let faulted = run_job(&na, 3, 1, 8).unwrap();
    cse::fault::disarm();

    assert!(cse::fault::injected() > before, "the armed spec must actually fire");
    assert!(faulted.retries > 0, "every injected panic costs one retry");
    assert_eq!(clean.e.data, faulted.e.data, "recovery must be bitwise invisible");
    assert_eq!(clean.matvecs, faulted.matvecs, "retries must not bill extra matvecs");
}

#[test]
fn injected_delays_reorder_shard_completion_but_not_bits() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let na = test_graph();
    cse::fault::disarm();
    let clean = run_job(&na, 4, 1, 8).unwrap();

    let before = cse::fault::injected();
    cse::fault::arm("shard_run:delay:p=0.5:ms=2:seed=3").unwrap();
    let delayed = run_job(&na, 4, 1, 8).unwrap();
    cse::fault::disarm();

    assert!(cse::fault::injected() > before);
    assert_eq!(delayed.retries, 0, "a delay is not a failure");
    assert_eq!(clean.e.data, delayed.e.data);
}

#[test]
fn poisoned_shards_trip_the_blowup_guard_and_are_retried_clean() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let na = test_graph();
    cse::fault::disarm();
    let clean = run_job(&na, 3, 1, 8).unwrap();

    // Poison corrupts a shard's accumulator with NaN after stage 0; the
    // non-finite guard must catch it (instead of NaN silently reaching
    // the output) and the retry must land a clean attempt at p = 0.5.
    // A generous budget makes retry exhaustion (0.5^31) impossible.
    cse::fault::arm("shard_run:poison:p=0.5:seed=5").unwrap();
    let poisoned = run_job(&na, 3, 1, 30).unwrap();
    cse::fault::disarm();

    assert!(poisoned.retries > 0, "every poison costs one blow-up retry");
    assert_eq!(clean.e.data, poisoned.e.data, "no NaN may survive into the output");
    assert!(poisoned.e.data.iter().all(|v| v.is_finite()));
}

#[test]
fn exhausted_retry_budget_fails_typed_and_coordinator_survives() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let na = test_graph();
    cse::fault::disarm();
    let clean = run_job(&na, 2, 1, 8).unwrap();

    cse::fault::arm("shard_run:panic:p=1.0:seed=1").unwrap();
    let err = run_job(&na, 2, 1, 1).unwrap_err();
    cse::fault::disarm();

    match err {
        JobError::ShardFailed { attempts, ref reason, .. } => {
            assert_eq!(attempts, 2, "budget of 1 retry = 2 attempts");
            assert!(reason.contains("fault injected"), "reason carries the payload: {reason}");
        }
        other => panic!("expected ShardFailed, got {other:?}"),
    }
    // The process survived a certain-panic storm; the same pool now
    // runs a healthy job to the same bits as before.
    let after = run_job(&na, 2, 1, 8).unwrap();
    assert_eq!(clean.e.data, after.e.data);
}

#[test]
fn pool_task_panics_inside_kernels_are_contained() {
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let na = test_graph();
    cse::fault::disarm();
    let clean = run_job(&na, 2, 3, 8).unwrap();

    // Faults at the pool-task site unwind out of the kernel region into
    // the shard attempt, which catches and retries — two layers below
    // the coordinator. The site draws once per helper claim, and a
    // shard attempt spans dozens of kernel regions, so p stays tiny
    // (each fire dooms the whole attempt) and the retry budget large.
    let before = cse::fault::injected();
    cse::fault::arm("pool_task:panic:p=0.002:seed=9").unwrap();
    let faulted = run_job(&na, 2, 3, 50).unwrap();
    cse::fault::disarm();

    assert_eq!(clean.e.data, faulted.e.data, "pool-level recovery must be bitwise invisible");
    if cse::fault::injected() > before {
        assert!(faulted.retries > 0, "a fired pool fault must have cost a shard retry");
    }
}
