//! Cross-layer determinism of the parallel execution layer
//! (`cse::par`): every hot path it touches — SpMM, matvec, transpose,
//! the FastEmbed recursion, the coordinator pipeline, the eigensolvers,
//! SimHash builds and K-means — must produce results bitwise-identical
//! to the serial path for threads ∈ {1, 2, 4} under a fixed seed.

use cse::cluster::{kmeans, KmeansParams};
use cse::coordinator::{Coordinator, EmbedJob};
use cse::eigen::lanczos::{lanczos, LanczosParams};
use cse::eigen::rsvd::{rsvd, RsvdParams};
use cse::eigen::simult::simultaneous_iteration;
use cse::embed::{FastEmbed, Params};
use cse::funcs::SpectralFn;
use cse::index::{SimHashIndex, SimHashParams};
use cse::linalg::Mat;
use cse::par::ExecPolicy;
use cse::sparse::coo::Coo;
use cse::sparse::{gen, graph, Csr};
use cse::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> Csr {
    let mut coo = Coo::new(rows, cols);
    for _ in 0..nnz {
        coo.push(rng.below(rows), rng.below(cols), rng.normal());
    }
    Csr::from_coo(&coo)
}

#[test]
fn spmm_and_matvec_bitwise_identical_across_threads() {
    let mut rng = Rng::new(41);
    for _ in 0..3 {
        let rows = 500 + rng.below(2000);
        let cols = 500 + rng.below(2000);
        let d = 1 + rng.below(12);
        let a = random_csr(&mut rng, rows, cols, rows * 6);
        let x = Mat::randn(&mut rng, cols, d);
        let xv: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let want = a.spmm(&x);
        let want_v = a.matvec(&xv);
        for threads in THREADS {
            let exec = ExecPolicy::with_threads(threads);
            assert_eq!(a.spmm_with(&x, &exec).data, want.data, "spmm @ {threads}");
            assert_eq!(a.matvec_with(&xv, &exec), want_v, "matvec @ {threads}");
        }
    }
}

#[test]
fn transpose_bitwise_identical_across_threads() {
    let mut rng = Rng::new(42);
    let a = random_csr(&mut rng, 3000, 1700, 15_000);
    let want = a.transpose();
    for threads in THREADS {
        let t = a.transpose_with(&ExecPolicy::with_threads(threads));
        assert_eq!(t.indptr, want.indptr, "{threads} threads");
        assert_eq!(t.indices, want.indices, "{threads} threads");
        assert_eq!(t.values, want.values, "{threads} threads");
    }
}

/// The tentpole acceptance check: the full fastembed pipeline, fixed
/// seed, is bitwise-identical at every thread count.
#[test]
fn fastembed_pipeline_thread_count_invariant() {
    let mut rng = Rng::new(43);
    let g = gen::sbm_by_degree(&mut rng, 1500, 10, 8.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let run = |threads: usize| {
        let fe = FastEmbed::new(Params {
            d: 24,
            order: 40,
            cascade: 2,
            exec: ExecPolicy::with_threads(threads),
            ..Params::default()
        });
        let mut r = Rng::new(7); // fixed seed per run
        fe.embed(&na, &SpectralFn::Step { c: 0.7 }, &mut r)
    };
    let base = run(1);
    for threads in [2usize, 4] {
        let emb = run(threads);
        assert_eq!(base.e.data, emb.e.data, "embedding differs at {threads} threads");
        assert_eq!(base.matvecs, emb.matvecs);
    }
}

#[test]
fn coordinator_pipeline_invariant_across_both_parallel_axes() {
    let mut rng = Rng::new(44);
    let g = gen::sbm_by_degree(&mut rng, 900, 6, 7.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let run = |workers: usize, threads: usize| {
        let mut job = EmbedJob::new(
            Params { d: 18, order: 24, cascade: 2, ..Params::default() },
            SpectralFn::Step { c: 0.6 },
            11,
        );
        job.params.exec = ExecPolicy::with_threads(threads);
        Coordinator::new(workers).run(&na, &job)
    };
    let base = run(1, 1);
    for (workers, threads) in [(1usize, 4usize), (2, 2), (4, 1), (3, 4)] {
        let res = run(workers, threads);
        assert_eq!(base.e.data, res.e.data, "workers={workers} threads={threads}");
        assert_eq!(base.matvecs, res.matvecs);
    }
}

#[test]
fn eigensolvers_thread_count_invariant() {
    let mut rng = Rng::new(45);
    let g = gen::sbm_by_degree(&mut rng, 700, 5, 9.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);

    let lan = |threads: usize| {
        let mut r = Rng::new(5);
        lanczos(
            &na,
            6,
            &LanczosParams { exec: ExecPolicy::with_threads(threads), ..Default::default() },
            &mut r,
        )
    };
    let rs = |threads: usize| {
        let mut r = Rng::new(6);
        rsvd(
            &na,
            6,
            &RsvdParams { exec: ExecPolicy::with_threads(threads), ..Default::default() },
            &mut r,
        )
    };
    let si = |threads: usize| {
        let mut r = Rng::new(8);
        simultaneous_iteration(&na, 6, 50, &mut r, &ExecPolicy::with_threads(threads))
    };

    let (l1, r1, s1) = (lan(1), rs(1), si(1));
    for threads in [2usize, 4] {
        let (lt, rt, st) = (lan(threads), rs(threads), si(threads));
        assert_eq!(l1.values, lt.values, "lanczos values @ {threads}");
        assert_eq!(l1.vectors.data, lt.vectors.data, "lanczos vectors @ {threads}");
        assert_eq!(r1.values, rt.values, "rsvd values @ {threads}");
        assert_eq!(r1.vectors.data, rt.vectors.data, "rsvd vectors @ {threads}");
        assert_eq!(s1.values, st.values, "simult values @ {threads}");
        assert_eq!(s1.vectors.data, st.vectors.data, "simult vectors @ {threads}");
    }
}

#[test]
fn simhash_and_kmeans_thread_count_invariant() {
    let mut rng = Rng::new(46);
    let e = Mat::randn(&mut rng, 2500, 12);
    let p = SimHashParams { tables: 4, bits: 8, probes: 4, seed: 21, ..Default::default() };
    let base_idx = SimHashIndex::build(&e, p);
    let base_km = {
        let mut r = Rng::new(3);
        kmeans(&e, &KmeansParams { k: 7, ..Default::default() }, &mut r)
    };
    for threads in [2usize, 4] {
        let exec = ExecPolicy::with_threads(threads);
        let idx = SimHashIndex::build(&e, SimHashParams { exec, ..p });
        for i in (0..e.rows).step_by(97) {
            assert_eq!(base_idx.candidates(e.row(i)), idx.candidates(e.row(i)));
            assert_eq!(base_idx.signatures(e.row(i)), idx.signatures(e.row(i)));
        }
        let mut r = Rng::new(3);
        let km = kmeans(&e, &KmeansParams { k: 7, exec, ..Default::default() }, &mut r);
        assert_eq!(base_km.assignment, km.assignment, "{threads} threads");
        assert_eq!(base_km.cost.to_bits(), km.cost.to_bits(), "{threads} threads");
    }
}
