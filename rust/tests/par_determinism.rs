//! Cross-layer determinism of the parallel execution layer
//! (`cse::par`): every hot path it touches — SpMM (including the
//! column-tiled fused axpby kernel, at any tile width, in both the CSR
//! and SELL-C-σ storage formats), matvec,
//! transpose, the FastEmbed recursion, the coordinator pipeline, the
//! eigensolvers
//! (now including the parallel MGS / Lanczos reorthogonalization),
//! SimHash builds and K-means (now including the parallel centroid
//! update) — must produce results bitwise-identical to the serial path
//! for threads ∈ {1, 2, 4} under a fixed seed. The persistent pool and
//! the workspace recycling must also be invisible: thousands of small
//! regions and repeated workspace-backed calls give the same bits as
//! fresh-allocation serial runs. The memory-locality layer rides the
//! same contract: NUMA first-touch placement, worker pinning, and
//! sticky partition reuse are all asserted bitwise-invisible below.

use std::sync::Mutex;

use cse::cluster::{kmeans, KmeansParams};
use cse::coordinator::{Coordinator, EmbedJob};
use cse::eigen::lanczos::{lanczos, LanczosParams};
use cse::eigen::rsvd::{rsvd, RsvdParams};
use cse::eigen::simult::simultaneous_iteration;
use cse::embed::fastembed::{apply_series, apply_series_ws};
use cse::embed::{FastEmbed, Params};
use cse::funcs::SpectralFn;
use cse::index::{SimHashIndex, SimHashParams};
use cse::linalg::qr::{mgs_orthonormalize, mgs_orthonormalize_with};
use cse::linalg::Mat;
use cse::par::{CancelToken, ExecPolicy, Workspace};
use cse::poly::legendre;
use cse::sparse::coo::Coo;
use cse::sparse::{gen, graph, Csr, KernelCfg, SellCs};
use cse::util::rng::Rng;

const THREADS: [usize; 3] = [1, 2, 4];

/// The fault-injection registry is process-global: tests that run
/// coordinator jobs while a `shard_run` spec may be armed must not
/// overlap, or one test's injected panics leak into the other's runs.
static SHARD_RUN_LOCK: Mutex<()> = Mutex::new(());

fn random_csr(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> Csr {
    let mut coo = Coo::new(rows, cols);
    for _ in 0..nnz {
        coo.push(rng.below(rows), rng.below(cols), rng.normal());
    }
    Csr::from_coo(&coo)
}

#[test]
fn spmm_and_matvec_bitwise_identical_across_threads() {
    let mut rng = Rng::new(41);
    for _ in 0..3 {
        let rows = 500 + rng.below(2000);
        let cols = 500 + rng.below(2000);
        let d = 1 + rng.below(12);
        let a = random_csr(&mut rng, rows, cols, rows * 6);
        let x = Mat::randn(&mut rng, cols, d);
        let xv: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let want = a.spmm(&x);
        let want_v = a.matvec(&xv);
        for threads in THREADS {
            let exec = ExecPolicy::with_threads(threads);
            assert_eq!(a.spmm_with(&x, &exec).data, want.data, "spmm @ {threads}");
            assert_eq!(a.matvec_with(&xv, &exec), want_v, "matvec @ {threads}");
        }
    }
}

/// The fused axpby kernel's determinism contract: bitwise-identical
/// output at any thread count AND any tile width, and bitwise-identical
/// to the unfused SpMM-then-elementwise expression it replaced.
#[test]
fn fused_axpby_bitwise_identical_across_threads_and_tile_widths() {
    let mut rng = Rng::new(50);
    for &d in &[1usize, 5, 8, 13, 24] {
        let rows = 400 + rng.below(800);
        let cols = 400 + rng.below(800);
        let a = random_csr(&mut rng, rows, cols, rows * 5);
        let x = Mat::randn(&mut rng, cols, d);
        let z = Mat::randn(&mut rng, rows, d);
        let (alpha, beta) = (1.75, -0.4);
        // Unfused reference: plain SpMM then the pinned elementwise
        // write-back expression.
        let mut want = a.spmm(&x);
        for (yv, zv) in want.data.iter_mut().zip(&z.data) {
            *yv = alpha * *yv + beta * zv;
        }
        let mut ws = Workspace::new();
        for threads in THREADS {
            let exec = ExecPolicy::with_threads(threads);
            let mut y = Mat::zeros(rows, d);
            a.spmm_axpby_into_ws(&x, alpha, beta, &z, &mut y, &exec, &mut ws);
            assert_eq!(y.data, want.data, "fused axpby d={d} @ {threads} threads");
        }
        // Tile-width invariance: capping the kernel at narrower lanes
        // (scalar-only, width-4, width-8) must not move a single bit.
        for max_tile in [1usize, 4, 8] {
            let mut y = Mat::zeros(rows, d);
            a.spmm_axpby_max_tile(&x, alpha, beta, &z, &mut y, max_tile);
            assert_eq!(y.data, want.data, "fused axpby d={d} max_tile={max_tile}");
        }
    }
}

/// Full pipeline bits must survive the tile-width cap too: an embedding
/// computed with the kernel forced scalar equals the lane-8 default.
#[test]
fn spmm_tile_width_invariant_under_plain_product() {
    let mut rng = Rng::new(51);
    let a = random_csr(&mut rng, 1200, 1200, 7200);
    for &d in &[3usize, 8, 17, 32] {
        let x = Mat::randn(&mut rng, 1200, d);
        let want = a.spmm(&x);
        let z = Mat::zeros(1200, d);
        for max_tile in [1usize, 4, 8] {
            let mut y = Mat::zeros(1200, d);
            a.spmm_axpby_max_tile(&x, 1.0, 0.0, &z, &mut y, max_tile);
            assert_eq!(y.data, want.data, "plain spmm d={d} max_tile={max_tile}");
        }
    }
}

#[test]
fn transpose_bitwise_identical_across_threads() {
    let mut rng = Rng::new(42);
    let a = random_csr(&mut rng, 3000, 1700, 15_000);
    let want = a.transpose();
    for threads in THREADS {
        let t = a.transpose_with(&ExecPolicy::with_threads(threads));
        assert_eq!(t.indptr, want.indptr, "{threads} threads");
        assert_eq!(t.indices, want.indices, "{threads} threads");
        assert_eq!(t.values, want.values, "{threads} threads");
    }
}

/// The tentpole acceptance check: the full fastembed pipeline, fixed
/// seed, is bitwise-identical at every thread count.
#[test]
fn fastembed_pipeline_thread_count_invariant() {
    let mut rng = Rng::new(43);
    let g = gen::sbm_by_degree(&mut rng, 1500, 10, 8.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let run = |threads: usize| {
        let fe = FastEmbed::new(Params {
            d: 24,
            order: 40,
            cascade: 2,
            exec: ExecPolicy::with_threads(threads),
            ..Params::default()
        });
        let mut r = Rng::new(7); // fixed seed per run
        fe.embed(&na, &SpectralFn::Step { c: 0.7 }, &mut r)
    };
    let base = run(1);
    for threads in [2usize, 4] {
        let emb = run(threads);
        assert_eq!(base.e.data, emb.e.data, "embedding differs at {threads} threads");
        assert_eq!(base.matvecs, emb.matvecs);
    }
}

#[test]
fn coordinator_pipeline_invariant_across_both_parallel_axes() {
    let _guard = SHARD_RUN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(44);
    let g = gen::sbm_by_degree(&mut rng, 900, 6, 7.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let run = |workers: usize, threads: usize| {
        let mut job = EmbedJob::new(
            Params { d: 18, order: 24, cascade: 2, ..Params::default() },
            SpectralFn::Step { c: 0.6 },
            11,
        );
        job.params.exec = ExecPolicy::with_threads(threads);
        Coordinator::new(workers).run(&na, &job).unwrap()
    };
    let base = run(1, 1);
    for (workers, threads) in [(1usize, 4usize), (2, 2), (4, 1), (3, 4)] {
        let res = run(workers, threads);
        assert_eq!(base.e.data, res.e.data, "workers={workers} threads={threads}");
        assert_eq!(base.matvecs, res.matvecs);
    }
}

/// Retry-path determinism: a run that recovers from injected shard
/// panics must be bitwise-identical to the fault-free run — a retried
/// shard re-executes from its own Ω column slice, so recovery is
/// invisible in both the embedding and the matvec accounting.
#[test]
fn injected_shard_panics_leave_the_embedding_bitwise_identical() {
    let _guard = SHARD_RUN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Rng::new(52);
    let g = gen::sbm_by_degree(&mut rng, 800, 6, 7.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);
    let run = || {
        let mut job = EmbedJob::new(
            Params { d: 20, order: 24, cascade: 2, ..Params::default() },
            SpectralFn::Step { c: 0.6 },
            17,
        );
        job.shard_width = 1; // 20 shards → 20+ deterministic fault draws
        job.max_retries = 30; // p=0.5: exhaustion (0.5^31) is impossible
        Coordinator::new(3).run(&na, &job).unwrap()
    };
    cse::fault::disarm();
    let clean = run();
    cse::fault::arm("shard_run:panic:p=0.5:seed=11").unwrap();
    let faulted = run();
    cse::fault::disarm();
    assert!(faulted.retries > 0, "p=0.5 over 20 shards should fire at least once");
    assert_eq!(clean.e.data, faulted.e.data, "retries must be bitwise invisible");
    assert_eq!(clean.matvecs, faulted.matvecs, "retries must not bill extra matvecs");
    assert_eq!(clean.retries, 0);
}

#[test]
fn eigensolvers_thread_count_invariant() {
    let mut rng = Rng::new(45);
    let g = gen::sbm_by_degree(&mut rng, 700, 5, 9.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);

    let lan = |threads: usize| {
        let mut r = Rng::new(5);
        lanczos(
            &na,
            6,
            &LanczosParams { exec: ExecPolicy::with_threads(threads), ..Default::default() },
            &mut r,
        )
    };
    let rs = |threads: usize| {
        let mut r = Rng::new(6);
        rsvd(
            &na,
            6,
            &RsvdParams { exec: ExecPolicy::with_threads(threads), ..Default::default() },
            &mut r,
        )
    };
    let si = |threads: usize| {
        let mut r = Rng::new(8);
        simultaneous_iteration(&na, 6, 50, &mut r, &ExecPolicy::with_threads(threads))
    };

    let (l1, r1, s1) = (lan(1), rs(1), si(1));
    for threads in [2usize, 4] {
        let (lt, rt, st) = (lan(threads), rs(threads), si(threads));
        assert_eq!(l1.values, lt.values, "lanczos values @ {threads}");
        assert_eq!(l1.vectors.data, lt.vectors.data, "lanczos vectors @ {threads}");
        assert_eq!(r1.values, rt.values, "rsvd values @ {threads}");
        assert_eq!(r1.vectors.data, rt.vectors.data, "rsvd vectors @ {threads}");
        assert_eq!(s1.values, st.values, "simult values @ {threads}");
        assert_eq!(s1.vectors.data, st.vectors.data, "simult vectors @ {threads}");
    }
}

#[test]
fn mgs_orthonormalize_thread_count_invariant() {
    let mut rng = Rng::new(47);
    for (m, n) in [(800usize, 24usize), (3000, 8), (64, 64)] {
        let a0 = Mat::randn(&mut rng, m, n);
        let mut base = a0.clone();
        let rank1 = mgs_orthonormalize(&mut base, 1e-12);
        for threads in [2usize, 4] {
            let mut at = a0.clone();
            let rankt = mgs_orthonormalize_with(&mut at, 1e-12, &ExecPolicy::with_threads(threads));
            assert_eq!(rank1, rankt, "{m}x{n} rank @ {threads} threads");
            assert_eq!(base.data, at.data, "{m}x{n} mgs differs @ {threads} threads");
        }
        // Sanity: actually orthonormal.
        let gram = base.tmatmul(&base);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((gram[(i, j)] - want).abs() < 1e-10, "gram[{i},{j}]");
            }
        }
    }
}

/// The persistent pool must be transparent under sustained micro-region
/// load: thousands of small kernels (the pool's worst case, where the
/// old scoped spawns dominated) still bitwise-match serial.
#[test]
fn pool_reuse_over_many_small_regions_matches_serial() {
    let mut rng = Rng::new(48);
    let a = random_csr(&mut rng, 300, 300, 1800);
    let x = Mat::randn(&mut rng, 300, 4);
    let want = a.spmm(&x);
    let exec = ExecPolicy::with_threads(4);
    let mut y = Mat::zeros(300, 4);
    let mut ws = Workspace::new();
    for _ in 0..1500 {
        a.spmm_into_ws(&x, &mut y, &exec, &mut ws);
        assert_eq!(y.data, want.data);
    }
}

/// Workspace recycling must be invisible: repeated `apply_series_ws`
/// calls through one warm workspace equal fresh-allocation calls, at
/// every thread count.
#[test]
fn workspace_reuse_is_bitwise_invisible() {
    let mut rng = Rng::new(49);
    let g = gen::erdos_renyi(&mut rng, 400, 1600);
    let na = graph::normalized_adjacency(&g.adj);
    let omega = Mat::randn(&mut rng, 400, 6);
    let series = legendre::step_coeffs(40, 0.6);
    let mut mv = 0usize;
    let want = apply_series(&na, &series, &omega, &mut mv, &ExecPolicy::serial());
    for threads in [1usize, 2, 4] {
        let exec = ExecPolicy::with_threads(threads);
        let mut ws = Workspace::new();
        for round in 0..4 {
            let mut mvr = 0usize;
            let e = apply_series_ws(&na, &series, &omega, &mut mvr, &exec, &mut ws);
            assert_eq!(e.data, want.data, "round {round} @ {threads} threads");
            assert_eq!(mvr, mv);
            ws.give_mat(e);
        }
    }
}

/// The SELL-C-σ backend's determinism contract: bitwise-identical to
/// CSR at every thread count × tile cap × slice height, on a matrix
/// deliberately containing empty rows and high-degree hub rows (the
/// shapes where padding and the σ-window sort actually engage).
#[test]
fn sell_matches_csr_bitwise_across_threads_tiles_and_slice_heights() {
    let mut rng = Rng::new(53);
    let (rows, cols) = (600usize, 500usize);
    let mut coo = Coo::new(rows, cols);
    for i in 0..rows {
        if i % 7 == 0 {
            continue; // empty row
        }
        for _ in 0..1 + rng.below(6) {
            coo.push(i, rng.below(cols), rng.normal());
        }
    }
    for &hub in &[0usize, 299, 598] {
        for _ in 0..200 {
            coo.push(hub, rng.below(cols), rng.normal());
        }
    }
    let a = Csr::from_coo(&coo);
    let (alpha, beta) = (1.75, -0.4);
    for &d in &[3usize, 8, 24] {
        let x = Mat::randn(&mut rng, cols, d);
        let z = Mat::randn(&mut rng, rows, d);
        // Unfused CSR reference.
        let mut want = a.spmm(&x);
        for (yv, zv) in want.data.iter_mut().zip(&z.data) {
            *yv = alpha * *yv + beta * zv;
        }
        let mut ws = Workspace::new();
        for &chunk in &[4usize, 8, 32] {
            let s = SellCs::from_csr(&a, chunk, 64).unwrap();
            for threads in THREADS {
                let exec = ExecPolicy::with_threads(threads);
                let mut y = Mat::zeros(rows, d);
                s.spmm_axpby_into_ws(&x, alpha, beta, &z, &mut y, &exec, &mut ws);
                assert_eq!(y.data, want.data, "sell C={chunk} d={d} @ {threads} threads");
            }
            for max_tile in [1usize, 4, 8] {
                let mut y = Mat::zeros(rows, d);
                s.spmm_axpby_max_tile(&x, alpha, beta, &z, &mut y, max_tile);
                assert_eq!(y.data, want.data, "sell C={chunk} d={d} max_tile={max_tile}");
            }
            // Autotuner-reachable configurations move block boundaries
            // only: a 16-lane cap and a tiny slice-block budget change
            // nothing either.
            for cfg in [
                KernelCfg { max_tile: 16, row_block_nnz: 16 * 1024 },
                KernelCfg { max_tile: 8, row_block_nnz: 1 },
            ] {
                let exec = ExecPolicy::with_threads(4);
                let mut y = Mat::zeros(rows, d);
                s.spmm_axpby_into_ws_cfg(&x, alpha, beta, &z, &mut y, &exec, &mut ws, cfg);
                assert_eq!(y.data, want.data, "sell C={chunk} d={d} cfg={cfg:?}");
            }
        }
    }
}

/// A cancelled workspace token must stop the SELL kernel at a slice
/// block boundary without writing: with the token tripped before the
/// call, a prefilled output comes back untouched (same contract as the
/// CSR row-block path).
#[test]
fn sell_cancel_leaves_prefilled_output_untouched() {
    let mut rng = Rng::new(54);
    let a = random_csr(&mut rng, 400, 400, 2400);
    let s = SellCs::from_csr_default(&a).unwrap();
    let x = Mat::randn(&mut rng, 400, 8);
    for threads in THREADS {
        let exec = ExecPolicy::with_threads(threads);
        let mut ws = Workspace::new();
        let token = CancelToken::new();
        token.cancel();
        ws.cancel = Some(token);
        let mut y = Mat::zeros(400, 8);
        y.data.fill(7.0);
        s.spmm_into_ws(&x, &mut y, &exec, &mut ws);
        assert!(
            y.data.iter().all(|&v| v == 7.0),
            "cancelled product wrote output @ {threads} threads"
        );
        // Clearing the token resumes normal (bitwise-correct) service
        // through the same workspace.
        ws.cancel = None;
        s.spmm_into_ws(&x, &mut y, &exec, &mut ws);
        assert_eq!(y.data, a.spmm(&x).data, "post-cancel product @ {threads} threads");
    }
}

/// NUMA first-touch placement must be bitwise-invisible: placed CSR and
/// SELL operators produce identical bits through both the plain and the
/// fused entry points at every thread count, and the repacked CSR
/// arrays are verbatim copies of the originals.
#[test]
fn numa_placement_is_bitwise_invisible() {
    let mut rng = Rng::new(55);
    let g = gen::barabasi_albert(&mut rng, 900, 4);
    let a = graph::normalized_adjacency(&g.adj);
    let d = 9;
    let x = Mat::randn(&mut rng, a.cols, d);
    let z = Mat::randn(&mut rng, a.rows, d);
    let (alpha, beta) = (0.75, -1.25);
    let want_plain = a.spmm(&x);
    let mut want = want_plain.clone();
    for (yv, zv) in want.data.iter_mut().zip(&z.data) {
        *yv = alpha * *yv + beta * zv;
    }
    let sell = SellCs::from_csr_default(&a).unwrap();
    for threads in THREADS {
        let exec = ExecPolicy::with_threads(threads);
        let mut ap = a.clone();
        ap.place(&exec);
        assert_eq!(ap.values, a.values, "placed CSR values must be a verbatim copy");
        assert_eq!(ap.indices, a.indices, "placed CSR indices must be a verbatim copy");
        assert_eq!(ap.indptr, a.indptr, "place must not touch indptr");
        let mut sp = sell.clone();
        sp.place(&exec);
        let mut ws = Workspace::new();
        let mut y = Mat::zeros(a.rows, d);
        ap.spmm_into_ws(&x, &mut y, &exec, &mut ws);
        assert_eq!(y.data, want_plain.data, "placed CSR plain spmm @ {threads} threads");
        ap.spmm_axpby_into_ws(&x, alpha, beta, &z, &mut y, &exec, &mut ws);
        assert_eq!(y.data, want.data, "placed CSR fused spmm @ {threads} threads");
        sp.spmm_into_ws(&x, &mut y, &exec, &mut ws);
        assert_eq!(y.data, want_plain.data, "placed SELL plain spmm @ {threads} threads");
        sp.spmm_axpby_into_ws(&x, alpha, beta, &z, &mut y, &exec, &mut ws);
        assert_eq!(y.data, want.data, "placed SELL fused spmm @ {threads} threads");
    }
}

/// Worker pinning is runtime policy only: with pinning enabled (whether
/// or not this build can actually pin — both paths must hold), parallel
/// products are bitwise-identical to the unpinned baseline.
#[test]
fn pinning_toggle_is_bitwise_invisible() {
    let mut rng = Rng::new(56);
    let a = random_csr(&mut rng, 800, 800, 4800);
    let x = Mat::randn(&mut rng, 800, 6);
    let want = a.spmm(&x);
    cse::par::affinity::set_pinning(true);
    let mut ws = Workspace::new();
    for threads in THREADS {
        let exec = ExecPolicy::with_threads(threads);
        let mut y = Mat::zeros(800, 6);
        a.spmm_into_ws(&x, &mut y, &exec, &mut ws);
        assert_eq!(y.data, want.data, "pinned spmm @ {threads} threads");
    }
    cse::par::affinity::set_pinning(false);
    // Topology detection always yields a usable (>= single-node) view.
    let topo = cse::par::topo::detect();
    assert!(topo.num_nodes() >= 1 && topo.physical_cores() >= 1);
    assert!(topo.physical_cores() <= topo.logical_cpus());
}

/// Sticky partition reuse must be invisible: one warm workspace serving
/// repeated products of one matrix, interleaved with a differently-shaped
/// matrix (forcing key misses and recomputes), returns the same bits as
/// fresh workspaces would every call.
#[test]
fn sticky_partitions_survive_matrix_swap_bitwise() {
    let mut rng = Rng::new(57);
    let a = random_csr(&mut rng, 700, 700, 4200);
    let b = random_csr(&mut rng, 500, 700, 1500);
    let x = Mat::randn(&mut rng, 700, 5);
    let want_a = a.spmm(&x);
    let want_b = b.spmm(&x);
    let exec = ExecPolicy::with_threads(4);
    let mut ws = Workspace::new();
    let mut ya = Mat::zeros(700, 5);
    let mut yb = Mat::zeros(500, 5);
    for round in 0..3 {
        a.spmm_into_ws(&x, &mut ya, &exec, &mut ws);
        assert_eq!(ya.data, want_a.data, "sticky round {round} matrix a");
        b.spmm_into_ws(&x, &mut yb, &exec, &mut ws);
        assert_eq!(yb.data, want_b.data, "sticky round {round} matrix b");
    }
    // SELL slice partitions stick independently of the CSR row ranges
    // (separate workspace fields), so mixing formats is safe too.
    let sa = SellCs::from_csr_default(&a).unwrap();
    for round in 0..3 {
        sa.spmm_into_ws(&x, &mut ya, &exec, &mut ws);
        assert_eq!(ya.data, want_a.data, "sticky SELL round {round}");
        a.spmm_into_ws(&x, &mut ya, &exec, &mut ws);
        assert_eq!(ya.data, want_a.data, "sticky CSR-after-SELL round {round}");
    }
}

#[test]
fn simhash_and_kmeans_thread_count_invariant() {
    let mut rng = Rng::new(46);
    let e = Mat::randn(&mut rng, 2500, 12);
    let p = SimHashParams { tables: 4, bits: 8, probes: 4, seed: 21, ..Default::default() };
    let base_idx = SimHashIndex::build(&e, p);
    let base_km = {
        let mut r = Rng::new(3);
        kmeans(&e, &KmeansParams { k: 7, ..Default::default() }, &mut r)
    };
    for threads in [2usize, 4] {
        let exec = ExecPolicy::with_threads(threads);
        let idx = SimHashIndex::build(&e, SimHashParams { exec, ..p });
        for i in (0..e.rows).step_by(97) {
            assert_eq!(base_idx.candidates(e.row(i)), idx.candidates(e.row(i)));
            assert_eq!(base_idx.signatures(e.row(i)), idx.signatures(e.row(i)));
        }
        let mut r = Rng::new(3);
        let km = kmeans(&e, &KmeansParams { k: 7, exec, ..Default::default() }, &mut r);
        assert_eq!(base_km.assignment, km.assignment, "{threads} threads");
        assert_eq!(base_km.cost.to_bits(), km.cost.to_bits(), "{threads} threads");
    }
}
