//! End-to-end integration: FastEmbed vs the exact (Lanczos) spectral
//! embedding on a community-structured graph — the system-level version
//! of Theorem 1, exercised through the public API exactly the way
//! `examples/quickstart.rs` uses it.

use cse::coordinator::{Coordinator, EmbedJob};
use cse::eigen::lanczos::{lanczos, LanczosParams};
use cse::embed::{FastEmbed, Params};
use cse::funcs::SpectralFn;
use cse::poly::Basis;
use cse::sparse::{gen, graph};
use cse::util::rng::Rng;
use cse::util::stats;

/// Build a small DBLP-analog and compare compressive vs exact normalized
/// correlations over random vertex pairs (the Figure-1a quantity).
#[test]
fn compressive_correlations_track_exact() {
    let mut rng = Rng::new(1);
    let n = 900;
    let k = 12;
    let g = gen::sbm_by_degree(&mut rng, n, k, 8.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);

    // Exact: top eigenvectors above the community band edge.
    let exact = lanczos(&na, k + 4, &LanczosParams::default(), &mut rng);
    let lam_k = exact.values[k - 1];
    let c = (lam_k - 0.02).max(0.5);
    let e_exact = exact.spectral_embedding(|x| if x >= c { 1.0 } else { 0.0 });

    // Compressive, through the same weighing function.
    let fe = FastEmbed::new(Params {
        d: 120,
        order: 160,
        cascade: 2,
        basis: Basis::Legendre,
        ..Params::default()
    });
    let emb = fe.embed(&na, &SpectralFn::Step { c }, &mut rng);

    // Sample pairs; compare normalized correlations.
    let mut devs = Vec::new();
    for _ in 0..3000 {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        let ce = e_exact.row_corr(i, j);
        let cg = emb.e.row_corr(i, j);
        devs.push((ce - cg).abs());
    }
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = stats::percentile(&devs, 50.0);
    let p95 = stats::percentile(&devs, 95.0);
    // Paper (Fig 1a at d=80): 90% of pairs within +-0.2. Our d=120 on a
    // smaller graph should do at least that well.
    assert!(p50 < 0.10, "median correlation deviation {p50}");
    assert!(p95 < 0.30, "p95 correlation deviation {p95}");
}

/// Same-community pairs must be far more correlated than cross-community
/// pairs in the compressive embedding (the property clustering uses).
#[test]
fn embedding_separates_planted_communities() {
    let mut rng = Rng::new(2);
    let n = 600;
    let g = gen::sbm_by_degree(&mut rng, n, 6, 10.0, 0.5);
    let labels = g.labels.clone().unwrap();
    let na = graph::normalized_adjacency(&g.adj);
    let fe = FastEmbed::new(Params { d: 64, order: 120, cascade: 2, ..Params::default() });
    let emb = fe.embed(&na, &SpectralFn::Step { c: 0.8 }, &mut rng);

    let mut within = Vec::new();
    let mut across = Vec::new();
    for _ in 0..4000 {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        let corr = emb.e.row_corr(i, j);
        if labels[i] == labels[j] {
            within.push(corr);
        } else {
            across.push(corr);
        }
    }
    let mw = stats::mean(&within);
    let ma = stats::mean(&across);
    assert!(
        mw > ma + 0.5,
        "within-community corr {mw} not separated from across {ma}"
    );
}

/// The coordinator path and the library path produce identical output,
/// and the coordinator telemetry is consistent.
#[test]
fn coordinator_matches_library_end_to_end() {
    let mut rng = Rng::new(3);
    let g = gen::sbm_by_degree(&mut rng, 400, 8, 6.0, 1.0);
    let na = graph::normalized_adjacency(&g.adj);

    let params = Params { d: 40, order: 60, cascade: 2, ..Params::default() };
    let f = SpectralFn::Step { c: 0.75 };
    let job = EmbedJob::new(params.clone(), f.clone(), 77);

    let coord = Coordinator::new(2);
    let res = coord.run(&na, &job).unwrap();

    // The library path with the same seed derives the same Ω.
    let mut rng2 = Rng::new(77);
    let omega = cse::embed::omega::rademacher_omega(&mut rng2, na.rows, 40);
    let fe = FastEmbed::new(params);
    let direct = fe.embed_with_omega(&na, &f, omega, &mut rng2);

    assert_eq!(res.e.data, direct.e.data, "coordinator output differs");
    assert_eq!(res.matvecs, direct.matvecs);
    assert_eq!(coord.metrics.snapshot().matvecs, res.matvecs);
}

/// Commute-time weighting (the §2 flexibility example) runs end to end
/// and produces larger norms for low-degree peripheral vertices than the
/// plain step embedding.
#[test]
fn commute_time_embedding_runs() {
    let mut rng = Rng::new(4);
    let g = gen::barabasi_albert(&mut rng, 500, 2);
    let na = graph::normalized_adjacency(&g.adj);
    let fe = FastEmbed::new(Params { d: 48, order: 80, cascade: 1, ..Params::default() });
    let emb = fe.embed(&na, &SpectralFn::CommuteTime { c: -1.0, eps: 0.05 }, &mut rng);
    assert_eq!(emb.e.rows, 500);
    // Finite output everywhere.
    assert!(emb.e.data.iter().all(|v| v.is_finite()));
}

/// General (rectangular) embedding: a bipartite-ish doc-term matrix,
/// checked for shape and finite values plus row/col consistency.
#[test]
fn general_matrix_embedding_end_to_end() {
    let mut rng = Rng::new(5);
    let (m, n) = (200, 120);
    let mut coo = cse::sparse::coo::Coo::new(m, n);
    for _ in 0..1500 {
        coo.push(rng.below(m), rng.below(n), rng.uniform(0.0, 1.0));
    }
    let a = cse::sparse::Csr::from_coo(&coo);
    let fe = FastEmbed::new(Params {
        d: 48,
        order: 80,
        cascade: 1,
        norm_est: Some(Default::default()),
        ..Params::default()
    });
    let ge = fe.embed_general(&a, &SpectralFn::Step { c: 0.3 }, &mut rng);
    assert_eq!(ge.rows.rows, m);
    assert_eq!(ge.cols.rows, n);
    assert!(ge.rows.data.iter().all(|v| v.is_finite()));
    assert!(ge.norm_estimate > 0.0);
}
