//! Nyström column-sampling eigendecomposition [6][7] — the O(ksn + s³)
//! family of approximations discussed in §2. Implemented for CSR
//! operators (needs explicit column access, not just matvecs).

use super::PartialEig;
use crate::linalg::eigh::jacobi_eigh;
use crate::linalg::Mat;
use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Rank-k Nyström approximation from `s >= k` uniformly sampled columns:
/// with C = A[:, idx] (n×s) and W = A[idx, idx] (s×s),
/// Â = C W⁺ Cᵀ; eigenpairs follow from W = UΛUᵀ via the standard
/// extension λ̂ = (n/s)·λ_W, v̂ = sqrt(s/n)·C u / λ_W.
pub fn nystrom(a: &Csr, k: usize, s: usize, rng: &mut Rng) -> PartialEig {
    let n = a.rows;
    assert_eq!(a.rows, a.cols, "nystrom needs a square (symmetric) matrix");
    let s = s.clamp(k.max(1), n);
    let mut idx = rng.sample_indices(n, s);
    idx.sort_unstable();

    // C = A[:, idx] (gather s columns; CSR rows are sorted by column).
    let mut c = Mat::zeros(n, s);
    let pos_of: std::collections::HashMap<usize, usize> =
        idx.iter().enumerate().map(|(p, &j)| (j, p)).collect();
    for i in 0..n {
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            if let Some(&p) = pos_of.get(&(j as usize)) {
                c[(i, p)] = v;
            }
        }
    }
    // W = A[idx, idx].
    let mut w = Mat::zeros(s, s);
    for (pi, &i) in idx.iter().enumerate() {
        let (cols, vals) = a.row(i);
        for (&j, &v) in cols.iter().zip(vals) {
            if let Some(&pj) = pos_of.get(&(j as usize)) {
                w[(pi, pj)] = v;
            }
        }
    }
    let (lam_w, u_w) = jacobi_eigh(&w);

    // Keep the k eigenpairs of W with largest |λ| above a pinv cutoff.
    let cutoff = lam_w.iter().fold(0.0f64, |m, &x| m.max(x.abs())) * 1e-10;
    let mut order: Vec<usize> = (0..s).collect();
    order.sort_by(|&i, &j| lam_w[j].abs().partial_cmp(&lam_w[i].abs()).unwrap());
    let kept: Vec<usize> = order
        .into_iter()
        .filter(|&i| lam_w[i].abs() > cutoff)
        .take(k)
        .collect();

    let scale_l = n as f64 / s as f64;
    let scale_v = (s as f64 / n as f64).sqrt();
    let mut values = Vec::with_capacity(kept.len());
    let mut vectors = Mat::zeros(n, kept.len());
    for (out_j, &wi) in kept.iter().enumerate() {
        values.push(scale_l * lam_w[wi]);
        let u = u_w.col(wi);
        for i in 0..n {
            let mut acc = 0.0;
            let crow = c.row(i);
            for (p, &cv) in crow.iter().enumerate() {
                acc += cv * u[p];
            }
            vectors[(i, out_j)] = scale_v * acc / lam_w[wi];
        }
    }
    // Sort by algebraic value descending for consistency with lanczos.
    let mut ord: Vec<usize> = (0..values.len()).collect();
    ord.sort_by(|&i, &j| values[j].partial_cmp(&values[i]).unwrap());
    let sorted_vals: Vec<f64> = ord.iter().map(|&i| values[i]).collect();
    let mut sorted_vecs = Mat::zeros(n, values.len());
    for (nj, &oj) in ord.iter().enumerate() {
        let col = vectors.col(oj);
        sorted_vecs.set_col(nj, &col);
    }
    PartialEig { values: sorted_vals, vectors: sorted_vecs, matvecs: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    #[test]
    fn exact_when_all_columns_sampled_low_rank() {
        // Rank-2 PSD matrix: Nystrom with s = n must recover it exactly.
        let n = 12;
        let mut rng = Rng::new(181);
        let b = Mat::randn(&mut rng, n, 2);
        let full = b.matmul(&b.transpose());
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            for j in 0..n {
                coo.push(i, j, full[(i, j)]);
            }
        }
        let a = Csr::from_coo(&coo);
        let pe = nystrom(&a, 2, n, &mut rng);
        // Reconstruct V diag(lam) V^T and compare.
        let mut rec = Mat::zeros(n, n);
        for t in 0..pe.values.len() {
            let v = pe.vectors.col(t);
            for i in 0..n {
                for j in 0..n {
                    rec[(i, j)] += pe.values[t] * v[i] * v[j];
                }
            }
        }
        assert!(
            rec.max_abs_diff(&full) < 1e-8,
            "nystrom full-sample reconstruction err {}",
            rec.max_abs_diff(&full)
        );
    }

    #[test]
    fn approximates_leading_eigenvalue_of_graph() {
        let mut rng = Rng::new(182);
        let g = crate::sparse::gen::sbm_by_degree(&mut rng, 300, 3, 20.0, 0.5);
        let na = crate::sparse::graph::normalized_adjacency(&g.adj);
        let pe = nystrom(&na, 4, 150, &mut rng);
        // Sampling half the columns of a strongly structured graph should
        // put the leading eigenvalue in the right ballpark.
        assert!(
            (pe.values[0] - 1.0).abs() < 0.4,
            "nystrom lead {} (want ~1)",
            pe.values[0]
        );
    }

    #[test]
    fn handles_more_requested_than_rank() {
        let mut coo = Coo::new(6, 6);
        coo.push(0, 0, 1.0); // rank-1
        let a = Csr::from_coo(&coo);
        let mut rng = Rng::new(183);
        let pe = nystrom(&a, 5, 6, &mut rng);
        assert!(pe.values.len() <= 5);
    }
}
