//! Eigensolver baselines the paper compares against.
//!
//! * [`lanczos`] — full-reorthogonalization Lanczos: our stand-in for the
//!   ARPACK/`eigs` "exact" baseline (DESIGN.md §3), also the ground truth
//!   for every accuracy experiment.
//! * [`simult`] — simultaneous (orthogonal) iteration, the other classic
//!   `Ω(kT)` iterative solver named in §2.
//! * [`rsvd`] — Randomized SVD (Halko et al. [8]), the approximate
//!   baseline of the Amazon clustering experiment (q=5, l=10).
//! * [`nystrom`] — Nyström column-sampling approximation [6][7].
//!
//! All solvers work on any [`crate::embed::op::Operator`], so they drive
//! the same SpMM hot path as FastEmbed — timing comparisons measure
//! algorithmic cost, not implementation skew.

pub mod lanczos;
pub mod nystrom;
pub mod rsvd;
pub mod simult;

use crate::linalg::Mat;

/// A partial eigendecomposition: `k` eigenvalues (descending by the
/// solver's ordering criterion) with eigenvectors as columns of `vectors`.
pub struct PartialEig {
    pub values: Vec<f64>,
    /// n×k, column i pairs with values[i].
    pub vectors: Mat,
    /// Operator applications consumed.
    pub matvecs: usize,
}

impl PartialEig {
    /// The spectral embedding E = [f(λ₁)v₁ … f(λ_k)v_k] (n×k) this
    /// decomposition induces — what FastEmbed approximates compressively.
    pub fn spectral_embedding(&self, f: impl Fn(f64) -> f64) -> Mat {
        let mut e = self.vectors.clone();
        for (j, &l) in self.values.iter().enumerate() {
            let fl = f(l);
            for i in 0..e.rows {
                e[(i, j)] *= fl;
            }
        }
        e
    }
}
