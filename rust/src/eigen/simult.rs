//! Simultaneous (orthogonal) iteration [13] — the second classic Ω(kT)
//! iterative eigensolver named in §2. Converges on the dominant-|λ|
//! invariant subspace; a final Rayleigh–Ritz rotation yields eigenpairs.
//! Generic over [`Operator`]: the filtered block products run on
//! whichever sparse backend the caller built (CSR or SELL-C-σ behind
//! `crate::sparse::SparseMat`), with bitwise-identical results.

use super::PartialEig;
use crate::embed::fastembed::apply_series_ws;
use crate::embed::op::{Operator, ScaledOp};
use crate::linalg::eigh::jacobi_eigh;
use crate::linalg::qr::mgs_orthonormalize_ws;
use crate::linalg::Mat;
use crate::par::{ExecPolicy, Workspace};
use crate::poly::{Basis, Series};
use crate::util::rng::Rng;

/// Top-`k` (largest |λ|) eigenpairs by simultaneous iteration with `iters`
/// rounds of orthogonalized block power iteration. Block products *and*
/// the re-orthonormalization run on `exec`'s pool, drawing scratch from
/// one workspace so iterations allocate nothing in steady state.
pub fn simultaneous_iteration(
    op: &(impl Operator + ?Sized),
    k: usize,
    iters: usize,
    rng: &mut Rng,
    exec: &ExecPolicy,
) -> PartialEig {
    simultaneous_iteration_filtered(op, k, iters, 1, 1.0, rng, exec)
}

/// [`simultaneous_iteration`] with a Chebyshev polynomial filter: each
/// round applies `T_ℓ(S / bulk_edge)` to the block instead of `S`
/// (`ℓ = filter_order`; `filter_order <= 1` degenerates to the plain
/// power step). On [−bulk_edge, bulk_edge] the filter stays bounded by 1
/// while growing like `cosh(ℓ·acosh(λ/bulk_edge))` outside, so bulk
/// modes are damped exponentially faster per orthogonalization and the
/// same accuracy needs fewer total matvecs. The filter rides the fused
/// three-term recurrence in [`apply_series_ws`], so every interior step
/// is a single output pass. The final Rayleigh–Ritz step uses `S`
/// itself, recovering `S`'s eigenvalues (not the filtered ones).
pub fn simultaneous_iteration_filtered(
    op: &(impl Operator + ?Sized),
    k: usize,
    iters: usize,
    filter_order: usize,
    bulk_edge: f64,
    rng: &mut Rng,
    exec: &ExecPolicy,
) -> PartialEig {
    assert!(bulk_edge > 0.0, "bulk_edge must be positive");
    let n = op.dim();
    let k = k.min(n);
    let mut ws = Workspace::new();
    let mut q = Mat::randn(rng, n, k);
    mgs_orthonormalize_ws(&mut q, 1e-12, exec, &mut ws);
    let mut y = Mat::zeros(n, k);
    let mut matvecs = 0;
    // T_ℓ as a Chebyshev series: coefficient 1 on the top term.
    let filter = (filter_order > 1).then(|| {
        let mut coeffs = vec![0.0; filter_order + 1];
        coeffs[filter_order] = 1.0;
        Series { basis: Basis::Chebyshev, coeffs }
    });
    for _ in 0..iters {
        match &filter {
            Some(series) => {
                let scaled = ScaledOp::new(op, 1.0 / bulk_edge, 0.0);
                let next = apply_series_ws(&scaled, series, &q, &mut matvecs, exec, &mut ws);
                ws.give_mat(std::mem::replace(&mut q, next));
            }
            None => {
                op.apply_into_ws(&q, &mut y, exec, &mut ws);
                matvecs += k;
                std::mem::swap(&mut q, &mut y);
            }
        }
        mgs_orthonormalize_ws(&mut q, 1e-12, exec, &mut ws);
    }
    // Rayleigh–Ritz: T = Qᵀ S Q, rotate Q by T's eigenvectors.
    op.apply_into_ws(&q, &mut y, exec, &mut ws);
    matvecs += k;
    let t = q.tmatmul(&y);
    // Symmetrize numerical noise.
    let mut ts = t.clone();
    for i in 0..k {
        for j in 0..k {
            ts[(i, j)] = (t[(i, j)] + t[(j, i)]) / 2.0;
        }
    }
    let (theta, z) = jacobi_eigh(&ts);
    let vectors = q.matmul(&z);
    PartialEig { values: theta, vectors, matvecs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::linalg::eigh::jacobi_eigh as dense_eigh;
    use crate::sparse::{gen, graph};
    use crate::testing::gen::sym_contraction;

    #[test]
    fn converges_to_dominant_eigenpairs() {
        let mut rng = Rng::new(161);
        let n = 16;
        let a = Mat::from_vec(n, n, sym_contraction(&mut rng, n));
        let (lam, _) = dense_eigh(&a);
        let pe =
            simultaneous_iteration(&DenseOp(a.clone()), 3, 300, &mut rng, &ExecPolicy::serial());
        // Dominant |lambda| values; compare magnitudes against the full set.
        let mut abs_lam: Vec<f64> = lam.iter().map(|x| x.abs()).collect();
        abs_lam.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut got: Vec<f64> = pe.values.iter().map(|x| x.abs()).collect();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for i in 0..3 {
            assert!(
                (got[i] - abs_lam[i]).abs() < 1e-6,
                "|eig| {i}: {} vs {}",
                got[i],
                abs_lam[i]
            );
        }
        // Residuals.
        for i in 0..3 {
            let v = Mat::from_vec(n, 1, pe.vectors.col(i));
            let mut r = a.matmul(&v);
            r.axpy(-pe.values[i], &v);
            assert!(r.frob_norm() < 1e-5, "residual {}", r.frob_norm());
        }
    }

    #[test]
    fn chebyshev_filter_matches_plain_and_saves_matvecs() {
        let mut rng = Rng::new(163);
        let n = 24;
        // Controlled spectrum: four leading eigenvalues in [0.93, 0.99],
        // well outside the bulk edge 0.5; the rest inside [−0.27, 0.37].
        let mut basis = Mat::randn(&mut rng, n, n);
        crate::linalg::qr::mgs_orthonormalize(&mut basis, 1e-12);
        let mut s = Mat::zeros(n, n);
        for t in 0..n {
            let lam = if t < 4 {
                0.93 + 0.02 * t as f64
            } else {
                -0.4 + 0.8 * t as f64 / n as f64
            };
            let col = basis.col(t);
            for i in 0..n {
                for j in 0..n {
                    s[(i, j)] += lam * col[i] * col[j];
                }
            }
        }
        let plain =
            simultaneous_iteration(&DenseOp(s.clone()), 4, 100, &mut rng, &ExecPolicy::serial());
        let filt = simultaneous_iteration_filtered(
            &DenseOp(s.clone()),
            4,
            15,
            3,
            0.5,
            &mut rng,
            &ExecPolicy::serial(),
        );
        // T_3(λ/0.5) ≥ 20 on the leading eigenvalues vs ≤ 1 on the bulk,
        // so 15 filtered rounds out-converge 100 plain rounds at under
        // half the matvec budget — and Rayleigh–Ritz on S itself means
        // both report S's (unfiltered) eigenvalues.
        for i in 0..4 {
            assert!(
                (plain.values[i] - filt.values[i]).abs() < 1e-8,
                "eig {i}: plain {} vs filtered {}",
                plain.values[i],
                filt.values[i]
            );
        }
        assert!(
            filt.matvecs < plain.matvecs,
            "filtered {} vs plain {} matvecs",
            filt.matvecs,
            plain.matvecs
        );
        for i in 0..4 {
            let v = Mat::from_vec(n, 1, filt.vectors.col(i));
            let mut r = s.matmul(&v);
            r.axpy(-filt.values[i], &v);
            assert!(r.frob_norm() < 1e-7, "filtered residual {i}: {}", r.frob_norm());
        }
    }

    #[test]
    fn works_on_sparse_graph() {
        let mut rng = Rng::new(162);
        let g = gen::sbm_by_degree(&mut rng, 300, 3, 10.0, 0.5);
        let na = graph::normalized_adjacency(&g.adj);
        let pe = simultaneous_iteration(&na, 4, 200, &mut rng, &ExecPolicy::serial());
        assert!((pe.values[0] - 1.0).abs() < 1e-6, "lead {}", pe.values[0]);
        assert!(pe.matvecs >= 4 * 200);
    }
}
