//! Simultaneous (orthogonal) iteration [13] — the second classic Ω(kT)
//! iterative eigensolver named in §2. Converges on the dominant-|λ|
//! invariant subspace; a final Rayleigh–Ritz rotation yields eigenpairs.

use super::PartialEig;
use crate::embed::op::Operator;
use crate::linalg::eigh::jacobi_eigh;
use crate::linalg::qr::mgs_orthonormalize_ws;
use crate::linalg::Mat;
use crate::par::{ExecPolicy, Workspace};
use crate::util::rng::Rng;

/// Top-`k` (largest |λ|) eigenpairs by simultaneous iteration with `iters`
/// rounds of orthogonalized block power iteration. Block products *and*
/// the re-orthonormalization run on `exec`'s pool, drawing scratch from
/// one workspace so iterations allocate nothing in steady state.
pub fn simultaneous_iteration(
    op: &(impl Operator + ?Sized),
    k: usize,
    iters: usize,
    rng: &mut Rng,
    exec: &ExecPolicy,
) -> PartialEig {
    let n = op.dim();
    let k = k.min(n);
    let mut ws = Workspace::new();
    let mut q = Mat::randn(rng, n, k);
    mgs_orthonormalize_ws(&mut q, 1e-12, exec, &mut ws);
    let mut y = Mat::zeros(n, k);
    let mut matvecs = 0;
    for _ in 0..iters {
        op.apply_into_ws(&q, &mut y, exec, &mut ws);
        matvecs += k;
        std::mem::swap(&mut q, &mut y);
        mgs_orthonormalize_ws(&mut q, 1e-12, exec, &mut ws);
    }
    // Rayleigh–Ritz: T = Qᵀ S Q, rotate Q by T's eigenvectors.
    op.apply_into_ws(&q, &mut y, exec, &mut ws);
    matvecs += k;
    let t = q.tmatmul(&y);
    // Symmetrize numerical noise.
    let mut ts = t.clone();
    for i in 0..k {
        for j in 0..k {
            ts[(i, j)] = (t[(i, j)] + t[(j, i)]) / 2.0;
        }
    }
    let (theta, z) = jacobi_eigh(&ts);
    let vectors = q.matmul(&z);
    PartialEig { values: theta, vectors, matvecs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::linalg::eigh::jacobi_eigh as dense_eigh;
    use crate::sparse::{gen, graph};
    use crate::testing::gen::sym_contraction;

    #[test]
    fn converges_to_dominant_eigenpairs() {
        let mut rng = Rng::new(161);
        let n = 16;
        let a = Mat::from_vec(n, n, sym_contraction(&mut rng, n));
        let (lam, _) = dense_eigh(&a);
        let pe =
            simultaneous_iteration(&DenseOp(a.clone()), 3, 300, &mut rng, &ExecPolicy::serial());
        // Dominant |lambda| values; compare magnitudes against the full set.
        let mut abs_lam: Vec<f64> = lam.iter().map(|x| x.abs()).collect();
        abs_lam.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let mut got: Vec<f64> = pe.values.iter().map(|x| x.abs()).collect();
        got.sort_by(|a, b| b.partial_cmp(a).unwrap());
        for i in 0..3 {
            assert!(
                (got[i] - abs_lam[i]).abs() < 1e-6,
                "|eig| {i}: {} vs {}",
                got[i],
                abs_lam[i]
            );
        }
        // Residuals.
        for i in 0..3 {
            let v = Mat::from_vec(n, 1, pe.vectors.col(i));
            let mut r = a.matmul(&v);
            r.axpy(-pe.values[i], &v);
            assert!(r.frob_norm() < 1e-5, "residual {}", r.frob_norm());
        }
    }

    #[test]
    fn works_on_sparse_graph() {
        let mut rng = Rng::new(162);
        let g = gen::sbm_by_degree(&mut rng, 300, 3, 10.0, 0.5);
        let na = graph::normalized_adjacency(&g.adj);
        let pe = simultaneous_iteration(&na, 4, 200, &mut rng, &ExecPolicy::serial());
        assert!((pe.values[0] - 1.0).abs() < 1e-6, "lead {}", pe.values[0]);
        assert!(pe.matvecs >= 4 * 200);
    }
}
