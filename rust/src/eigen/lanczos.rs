//! Lanczos with full reorthogonalization.
//!
//! The "exact" baseline: for our problem sizes full reorthogonalization
//! drives residuals to machine precision, matching what ARPACK delivers
//! on the paper's testbed. Cost is Ω(k·T) matvecs + O(n·m²) reorth work —
//! exactly the scaling wall (§1 bottleneck (a)) FastEmbed sidesteps.

use super::PartialEig;
use crate::embed::op::Operator;
use crate::linalg::eigh::tridiag_eigh;
use crate::linalg::Mat;
use crate::par::ExecPolicy;
use crate::util::rng::Rng;

/// Parameters for [`lanczos`].
#[derive(Clone, Copy, Debug)]
pub struct LanczosParams {
    /// Krylov subspace size m; `None` → `min(n, 2k + 40)`.
    pub subspace: Option<usize>,
    /// Residual tolerance for counting an eigenpair converged.
    pub tol: f64,
    /// Threading for the matvecs (the reorthogonalization stays serial).
    pub exec: ExecPolicy,
}

impl Default for LanczosParams {
    fn default() -> Self {
        LanczosParams { subspace: None, tol: 1e-10, exec: ExecPolicy::serial() }
    }
}

/// Top-`k` (largest algebraic) eigenpairs of a symmetric operator.
pub fn lanczos(
    op: &(impl Operator + ?Sized),
    k: usize,
    params: &LanczosParams,
    rng: &mut Rng,
) -> PartialEig {
    let n = op.dim();
    let k = k.min(n);
    let m = params.subspace.unwrap_or(2 * k + 40).clamp(k, n);

    // Krylov basis as rows (contiguous vectors).
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut alpha = Vec::with_capacity(m);
    let mut beta: Vec<f64> = Vec::with_capacity(m);
    let mut matvecs = 0;

    let mut v = vec![0.0; n];
    for x in v.iter_mut() {
        *x = rng.normal();
    }
    normalize(&mut v);

    let mut x_buf = Mat::zeros(n, 1);
    let mut y_buf = Mat::zeros(n, 1);

    for j in 0..m {
        // w = S v_j
        x_buf.data.copy_from_slice(&v);
        op.apply_into(&x_buf, &mut y_buf, &params.exec);
        matvecs += 1;
        let mut w = y_buf.data.clone();
        // alpha_j = v_j . w
        let a: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
        alpha.push(a);
        // w -= alpha_j v_j + beta_{j-1} v_{j-1}
        for (wi, vi) in w.iter_mut().zip(&v) {
            *wi -= a * vi;
        }
        if j > 0 {
            let b = beta[j - 1];
            for (wi, vi) in w.iter_mut().zip(&basis[j - 1]) {
                *wi -= b * vi;
            }
        }
        basis.push(v.clone());
        // Full reorthogonalization (twice) against all previous vectors.
        for _ in 0..2 {
            for u in &basis {
                let d: f64 = u.iter().zip(&w).map(|(a, b)| a * b).sum();
                if d.abs() > 0.0 {
                    for (wi, ui) in w.iter_mut().zip(u) {
                        *wi -= d * ui;
                    }
                }
            }
        }
        let b = norm(&w);
        if j + 1 == m {
            break;
        }
        if b < 1e-13 {
            // Invariant subspace found: restart with a fresh random
            // direction orthogonal to the basis.
            let mut fresh = vec![0.0; n];
            for x in fresh.iter_mut() {
                *x = rng.normal();
            }
            for u in &basis {
                let d: f64 = u.iter().zip(&fresh).map(|(a, b)| a * b).sum();
                for (fi, ui) in fresh.iter_mut().zip(u) {
                    *fi -= d * ui;
                }
            }
            normalize(&mut fresh);
            beta.push(0.0);
            v = fresh;
        } else {
            beta.push(b);
            v = w;
            for x in v.iter_mut() {
                *x /= b;
            }
        }
    }

    // Rayleigh–Ritz on the tridiagonal T.
    let mm = alpha.len();
    let (theta, z) = tridiag_eigh(&alpha, &beta[..mm - 1]);
    let k = k.min(mm);
    let mut vectors = Mat::zeros(n, k);
    for col in 0..k {
        for (j, u) in basis.iter().enumerate() {
            let zj = z[(j, col)];
            if zj == 0.0 {
                continue;
            }
            for i in 0..n {
                vectors[(i, col)] += zj * u[i];
            }
        }
    }
    PartialEig { values: theta[..k].to_vec(), vectors, matvecs }
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v).max(1e-300);
    for x in v.iter_mut() {
        *x /= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::linalg::eigh::jacobi_eigh;
    use crate::sparse::{gen, graph};
    use crate::testing::gen::sym_contraction;
    use crate::testing::prop::{check, forall};

    #[test]
    fn lanczos_matches_jacobi_on_dense() {
        forall(
            151,
            6,
            |r| {
                let n = 8 + r.below(10);
                Mat::from_vec(n, n, sym_contraction(r, n))
            },
            |a| {
                let (lam, _) = jacobi_eigh(a);
                let mut rng = Rng::new(7);
                let k = 4;
                let pe = lanczos(
                    &DenseOp(a.clone()),
                    k,
                    &LanczosParams { subspace: Some(a.rows), ..Default::default() },
                    &mut rng,
                );
                for i in 0..k {
                    check(
                        (pe.values[i] - lam[i]).abs() < 1e-8,
                        format!("eig {i}: {} vs {}", pe.values[i], lam[i]),
                    )?;
                }
                // Residual check ||S v - lambda v||.
                for i in 0..k {
                    let v = Mat::from_vec(a.rows, 1, pe.vectors.col(i));
                    let sv = a.matmul(&v);
                    let mut res = sv.clone();
                    res.axpy(-pe.values[i], &v);
                    check(res.frob_norm() < 1e-7, format!("residual {i}: {}", res.frob_norm()))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lanczos_on_normalized_adjacency_leading_eig_one() {
        let mut rng = Rng::new(152);
        let g = gen::sbm_by_degree(&mut rng, 600, 6, 8.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let pe = lanczos(&na, 8, &LanczosParams::default(), &mut rng);
        assert!((pe.values[0] - 1.0).abs() < 1e-8, "lead {}", pe.values[0]);
        // SBM with 6 blocks: ~6 eigenvalues near 1, gap to the bulk.
        assert!(pe.values[5] > 0.5, "community eigs {:?}", &pe.values[..6]);
        assert!(pe.values[6] < pe.values[5]);
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        let mut rng = Rng::new(153);
        let g = gen::erdos_renyi(&mut rng, 200, 800);
        let na = graph::normalized_adjacency(&g.adj);
        let pe = lanczos(&na, 10, &LanczosParams::default(), &mut rng);
        let gram = pe.vectors.tmatmul(&pe.vectors);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram[(i, j)] - want).abs() < 1e-8,
                    "gram[{i},{j}] = {}",
                    gram[(i, j)]
                );
            }
        }
    }

    #[test]
    fn spectral_embedding_weights_columns() {
        let mut rng = Rng::new(154);
        let a = Mat::from_vec(6, 6, sym_contraction(&mut rng, 6));
        let pe = lanczos(
            &DenseOp(a),
            3,
            &LanczosParams { subspace: Some(6), ..Default::default() },
            &mut rng,
        );
        let e = pe.spectral_embedding(|x| if x >= pe.values[1] { 1.0 } else { 0.0 });
        // Columns 0,1 kept (norm ~1), column 2 zeroed.
        assert!(e.col_norm(0) > 0.9);
        assert!(e.col_norm(2) < 1e-12);
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let mut rng = Rng::new(155);
        let a = Mat::from_vec(5, 5, sym_contraction(&mut rng, 5));
        let pe = lanczos(&DenseOp(a), 50, &LanczosParams::default(), &mut rng);
        assert!(pe.values.len() <= 5);
    }
}
