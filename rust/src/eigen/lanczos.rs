//! Lanczos with full reorthogonalization.
//!
//! The "exact" baseline: for our problem sizes full reorthogonalization
//! drives residuals to machine precision, matching what ARPACK delivers
//! on the paper's testbed. Cost is Ω(k·T) matvecs + O(n·m²) reorth work —
//! exactly the scaling wall (§1 bottleneck (a)) FastEmbed sidesteps.
//! Generic over [`Operator`], so it runs unchanged on any sparse
//! backend (`crate::sparse::SparseMat` dispatches CSR or SELL-C-σ with
//! bitwise-identical matvecs).

use super::PartialEig;
use crate::embed::op::Operator;
use crate::linalg::eigh::tridiag_eigh;
use crate::linalg::Mat;
use crate::par::{self, ExecPolicy, Workspace};
use crate::util::rng::Rng;

/// Parameters for [`lanczos`].
#[derive(Clone, Copy, Debug)]
pub struct LanczosParams {
    /// Krylov subspace size m; `None` → `min(n, 2k + 40)`.
    pub subspace: Option<usize>,
    /// Residual tolerance for counting an eigenpair converged.
    pub tol: f64,
    /// Threading for the matvecs, the full reorthogonalization (basis
    /// dots fan out across the pool, the update stays in basis order so
    /// results are bitwise thread-count-independent), and the Ritz
    /// vector assembly.
    pub exec: ExecPolicy,
}

impl Default for LanczosParams {
    fn default() -> Self {
        LanczosParams { subspace: None, tol: 1e-10, exec: ExecPolicy::serial() }
    }
}

/// Top-`k` (largest algebraic) eigenpairs of a symmetric operator.
pub fn lanczos(
    op: &(impl Operator + ?Sized),
    k: usize,
    params: &LanczosParams,
    rng: &mut Rng,
) -> PartialEig {
    let n = op.dim();
    let k = k.min(n);
    let m = params.subspace.unwrap_or(2 * k + 40).clamp(k, n);
    let exec = &params.exec;

    // Krylov basis as rows (contiguous vectors).
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut alpha = Vec::with_capacity(m);
    let mut beta: Vec<f64> = Vec::with_capacity(m);
    let mut matvecs = 0;

    let mut v = vec![0.0; n];
    for x in v.iter_mut() {
        *x = rng.normal();
    }
    normalize(&mut v);

    // Persistent iteration buffers: the only per-iteration allocation
    // left is the basis vector itself (which must be retained anyway).
    // `x_prev` keeps the previous Lanczos vector so the beta-recurrence
    // term folds into the operator application as one fused pass.
    let mut ws = Workspace::new();
    let mut x_buf = Mat::zeros(n, 1);
    let mut x_prev = Mat::zeros(n, 1);
    let mut y_buf = Mat::zeros(n, 1);
    let mut w = vec![0.0; n];
    let mut dots = vec![0.0; m];
    let mut reorth = ReorthScratch::default();

    for j in 0..m {
        // w = S v_j − beta_{j−1} v_{j−1}, fused into one output pass.
        // (After a restart beta_{j−1} is exactly 0.0, so the fused call
        // degenerates to the plain product and never reads x_prev.)
        std::mem::swap(&mut x_buf, &mut x_prev);
        x_buf.data.copy_from_slice(&v);
        if j > 0 {
            op.apply_axpby_into_ws(&x_buf, 1.0, -beta[j - 1], &x_prev, &mut y_buf, exec, &mut ws);
        } else {
            op.apply_into_ws(&x_buf, &mut y_buf, exec, &mut ws);
        }
        matvecs += 1;
        w.copy_from_slice(&y_buf.data);
        // alpha_j = v_j . w (the beta term already subtracted above is
        // orthogonal to v_j to machine precision, so the Rayleigh
        // quotient is unchanged up to roundoff).
        let a: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
        alpha.push(a);
        // w -= alpha_j v_j
        for (wi, vi) in w.iter_mut().zip(&v) {
            *wi -= a * vi;
        }
        basis.push(v.clone());
        // Full reorthogonalization (twice) against all previous vectors.
        for _ in 0..2 {
            reorthogonalize(&mut w, &basis, &mut dots, exec, &mut reorth);
        }
        let b = norm(&w);
        if j + 1 == m {
            break;
        }
        if b < 1e-13 {
            // Invariant subspace found: restart with a fresh random
            // direction orthogonal to the basis.
            for x in w.iter_mut() {
                *x = rng.normal();
            }
            reorthogonalize(&mut w, &basis, &mut dots, exec, &mut reorth);
            normalize(&mut w);
            beta.push(0.0);
            std::mem::swap(&mut v, &mut w);
        } else {
            beta.push(b);
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / b;
            }
        }
    }

    // Rayleigh–Ritz on the tridiagonal T; basis combination fans out
    // over row ranges (element-wise in i, fixed j-then-col order per
    // element, so bitwise thread-count-independent).
    let mm = alpha.len();
    let (theta, z) = tridiag_eigh(&alpha, &beta[..mm - 1]);
    let k = k.min(mm);
    let mut vectors = Mat::zeros(n, k);
    let basis = &basis;
    let z = &z;
    let ranges = par::even_ranges(n, exec.chunks(n));
    exec.for_chunks(&ranges, &mut vectors.data, k, |_, rows, out| {
        for (local, i) in rows.enumerate() {
            let orow = &mut out[local * k..(local + 1) * k];
            for (j, u) in basis.iter().enumerate() {
                let ui = u[i];
                for (col, o) in orow.iter_mut().enumerate() {
                    let zj = z[(j, col)];
                    if zj == 0.0 {
                        continue;
                    }
                    *o += zj * ui;
                }
            }
        }
    });
    PartialEig { values: theta[..k].to_vec(), vectors, matvecs }
}

/// Sticky partition scratch for [`reorthogonalize`], reused across
/// Lanczos steps: the update-stage partition (over the fixed vector
/// length) is computed once per run, and the dots-stage partition only
/// when the growing basis changes its chunk count. Pure reuse of a
/// pure computation — bitwise-invisible.
#[derive(Default)]
struct ReorthScratch {
    dots: Vec<std::ops::Range<usize>>,
    dots_key: par::StickyKey,
    update: Vec<std::ops::Range<usize>>,
    update_key: par::StickyKey,
}

/// One classical Gram–Schmidt pass of `w` against `basis`, parallel and
/// deterministic: the basis dots fan out across the pool (each dot is a
/// serial full-length sum, so its bits don't depend on scheduling), then
/// every element of `w` subtracts its projections in fixed basis order.
/// Called twice per Lanczos step ("twice is enough"), this matches full
/// reorthogonalization to machine precision while parallelizing the
/// O(n·m) stage that used to be serial.
fn reorthogonalize(
    w: &mut [f64],
    basis: &[Vec<f64>],
    dots: &mut [f64],
    exec: &ExecPolicy,
    scratch: &mut ReorthScratch,
) {
    let nb = basis.len();
    if nb == 0 {
        return;
    }
    let _span = crate::obs::span(&crate::obs::LANCZOS_REORTH);
    let dots = &mut dots[..nb];
    {
        let w = &*w;
        par::even_ranges_sticky(nb, exec.chunks(nb), &mut scratch.dots, &mut scratch.dots_key);
        exec.for_chunks(&scratch.dots, dots, 1, |_, ks, out| {
            for (slot, k) in out.iter_mut().zip(ks) {
                *slot = basis[k].iter().zip(w).map(|(a, b)| a * b).sum();
            }
        });
    }
    let dots = &*dots;
    par::even_ranges_sticky(
        w.len(),
        exec.chunks(w.len()),
        &mut scratch.update,
        &mut scratch.update_key,
    );
    let ranges = &scratch.update;
    exec.for_chunks(ranges, w, 1, |_, is, out| {
        for (slot, i) in out.iter_mut().zip(is) {
            let mut acc = *slot;
            for (d, u) in dots.iter().zip(basis) {
                acc -= d * u[i];
            }
            *slot = acc;
        }
    });
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v).max(1e-300);
    for x in v.iter_mut() {
        *x /= n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::linalg::eigh::jacobi_eigh;
    use crate::sparse::{gen, graph};
    use crate::testing::gen::sym_contraction;
    use crate::testing::prop::{check, forall};

    #[test]
    fn lanczos_matches_jacobi_on_dense() {
        forall(
            151,
            6,
            |r| {
                let n = 8 + r.below(10);
                Mat::from_vec(n, n, sym_contraction(r, n))
            },
            |a| {
                let (lam, _) = jacobi_eigh(a);
                let mut rng = Rng::new(7);
                let k = 4;
                let pe = lanczos(
                    &DenseOp(a.clone()),
                    k,
                    &LanczosParams { subspace: Some(a.rows), ..Default::default() },
                    &mut rng,
                );
                for i in 0..k {
                    check(
                        (pe.values[i] - lam[i]).abs() < 1e-8,
                        format!("eig {i}: {} vs {}", pe.values[i], lam[i]),
                    )?;
                }
                // Residual check ||S v - lambda v||.
                for i in 0..k {
                    let v = Mat::from_vec(a.rows, 1, pe.vectors.col(i));
                    let sv = a.matmul(&v);
                    let mut res = sv.clone();
                    res.axpy(-pe.values[i], &v);
                    check(res.frob_norm() < 1e-7, format!("residual {i}: {}", res.frob_norm()))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn lanczos_on_normalized_adjacency_leading_eig_one() {
        let mut rng = Rng::new(152);
        let g = gen::sbm_by_degree(&mut rng, 600, 6, 8.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let pe = lanczos(&na, 8, &LanczosParams::default(), &mut rng);
        assert!((pe.values[0] - 1.0).abs() < 1e-8, "lead {}", pe.values[0]);
        // SBM with 6 blocks: ~6 eigenvalues near 1, gap to the bulk.
        assert!(pe.values[5] > 0.5, "community eigs {:?}", &pe.values[..6]);
        assert!(pe.values[6] < pe.values[5]);
    }

    #[test]
    fn eigenvectors_are_orthonormal() {
        let mut rng = Rng::new(153);
        let g = gen::erdos_renyi(&mut rng, 200, 800);
        let na = graph::normalized_adjacency(&g.adj);
        let pe = lanczos(&na, 10, &LanczosParams::default(), &mut rng);
        let gram = pe.vectors.tmatmul(&pe.vectors);
        for i in 0..10 {
            for j in 0..10 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (gram[(i, j)] - want).abs() < 1e-8,
                    "gram[{i},{j}] = {}",
                    gram[(i, j)]
                );
            }
        }
    }

    #[test]
    fn spectral_embedding_weights_columns() {
        let mut rng = Rng::new(154);
        let a = Mat::from_vec(6, 6, sym_contraction(&mut rng, 6));
        let pe = lanczos(
            &DenseOp(a),
            3,
            &LanczosParams { subspace: Some(6), ..Default::default() },
            &mut rng,
        );
        let e = pe.spectral_embedding(|x| if x >= pe.values[1] { 1.0 } else { 0.0 });
        // Columns 0,1 kept (norm ~1), column 2 zeroed.
        assert!(e.col_norm(0) > 0.9);
        assert!(e.col_norm(2) < 1e-12);
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let mut rng = Rng::new(155);
        let a = Mat::from_vec(5, 5, sym_contraction(&mut rng, 5));
        let pe = lanczos(&DenseOp(a), 50, &LanczosParams::default(), &mut rng);
        assert!(pe.values.len() <= 5);
    }
}
