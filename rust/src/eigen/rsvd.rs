//! Randomized SVD (Halko, Martinsson, Tropp [8]) for symmetric operators —
//! the approximate baseline of the paper's Amazon clustering comparison
//! (power iterates q=5, oversampling l=10).

use super::PartialEig;
use crate::embed::op::Operator;
use crate::linalg::eigh::jacobi_eigh;
use crate::linalg::qr::mgs_orthonormalize_ws;
use crate::linalg::Mat;
use crate::par::{ExecPolicy, Workspace};
use crate::util::rng::Rng;

/// Parameters (paper's comparison settings as defaults).
#[derive(Clone, Copy, Debug)]
pub struct RsvdParams {
    /// Power iterations q.
    pub power_iters: usize,
    /// Oversampling l (sketch width is k + l).
    pub oversample: usize,
    /// Threading for the block products and the inter-power
    /// re-orthonormalization (both deterministic at any thread count).
    pub exec: ExecPolicy,
}

impl Default for RsvdParams {
    fn default() -> Self {
        RsvdParams { power_iters: 5, oversample: 10, exec: ExecPolicy::serial() }
    }
}

/// Rank-k randomized eigendecomposition of a symmetric operator:
/// range finder Y = S^{q+1} Ω with re-orthonormalization between powers,
/// then Rayleigh–Ritz on the captured subspace.
pub fn rsvd(
    op: &(impl Operator + ?Sized),
    k: usize,
    params: &RsvdParams,
    rng: &mut Rng,
) -> PartialEig {
    let n = op.dim();
    let k = k.min(n);
    let p = (k + params.oversample).min(n);
    let exec = &params.exec;
    let mut ws = Workspace::new();
    let mut q = Mat::randn(rng, n, p);
    let mut y = Mat::zeros(n, p);
    let mut matvecs = 0;
    op.apply_into_ws(&q, &mut y, exec, &mut ws);
    matvecs += p;
    std::mem::swap(&mut q, &mut y);
    mgs_orthonormalize_ws(&mut q, 1e-12, exec, &mut ws);
    for _ in 0..params.power_iters {
        op.apply_into_ws(&q, &mut y, exec, &mut ws);
        matvecs += p;
        std::mem::swap(&mut q, &mut y);
        mgs_orthonormalize_ws(&mut q, 1e-12, exec, &mut ws);
    }
    // B = Qᵀ S Q (p×p), eigendecompose, keep top k by |λ|.
    op.apply_into_ws(&q, &mut y, exec, &mut ws);
    matvecs += p;
    let b = q.tmatmul(&y);
    let mut bs = b.clone();
    for i in 0..p {
        for j in 0..p {
            bs[(i, j)] = (b[(i, j)] + b[(j, i)]) / 2.0;
        }
    }
    let (theta, z) = jacobi_eigh(&bs);
    // jacobi returns descending by value; for embeddings of normalized
    // adjacencies the top-k algebraic is what partial SVD keeps.
    let zk = z.take_cols(k);
    let vectors = q.matmul(&zk);
    PartialEig { values: theta[..k].to_vec(), vectors, matvecs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eigen::lanczos::{lanczos, LanczosParams};
    use crate::sparse::{gen, graph};

    #[test]
    fn rsvd_close_to_lanczos_on_gapped_spectrum() {
        let mut rng = Rng::new(171);
        // deg_out = 2 keeps communities well connected (single lambda = 1,
        // no near-degenerate cluster that slows single-vector Lanczos).
        let g = gen::sbm_by_degree(&mut rng, 400, 4, 10.0, 2.0);
        let na = graph::normalized_adjacency(&g.adj);
        let exact = lanczos(
            &na,
            6,
            &LanczosParams { subspace: Some(120), ..Default::default() },
            &mut rng,
        );
        let approx = rsvd(&na, 6, &RsvdParams::default(), &mut rng);
        // q=5 power iterations leave O(1e-3..1e-2) error on the sub-leading
        // community eigenvalues — exactly the lossiness the paper observes.
        for i in 0..4 {
            assert!(
                (exact.values[i] - approx.values[i]).abs() < 1e-2,
                "eig {i}: {} vs {}",
                exact.values[i],
                approx.values[i]
            );
        }
    }

    #[test]
    fn fewer_power_iters_is_less_accurate() {
        // The q=5 vs q=0 accuracy ordering that motivates the paper's
        // "RSVD is fast but lossy" observation.
        let mut rng = Rng::new(172);
        let g = gen::sbm_by_degree(&mut rng, 500, 10, 6.0, 2.0);
        let na = graph::normalized_adjacency(&g.adj);
        let exact = lanczos(&na, 12, &LanczosParams::default(), &mut rng);
        let sum_err = |q: usize| -> f64 {
            let mut r2 = Rng::new(42);
            let p = RsvdParams { power_iters: q, oversample: 10, ..Default::default() };
            let pe = rsvd(&na, 12, &p, &mut r2);
            exact
                .values
                .iter()
                .zip(&pe.values)
                .map(|(a, b)| (a - b).abs())
                .sum()
        };
        assert!(sum_err(0) > sum_err(5), "q=0 err {} vs q=5 err {}", sum_err(0), sum_err(5));
    }

    #[test]
    fn matvec_budget_accounting() {
        let mut rng = Rng::new(173);
        let g = gen::erdos_renyi(&mut rng, 100, 300);
        let na = graph::normalized_adjacency(&g.adj);
        let p = RsvdParams { power_iters: 2, oversample: 5, ..Default::default() };
        let pe = rsvd(&na, 5, &p, &mut rng);
        assert_eq!(pe.matvecs, 10 * 4); // (k+l) * (1 + q + 1)
    }
}
