//! Dense linear-algebra substrate (no BLAS/LAPACK offline).
//!
//! [`dense::Mat`] is a row-major `f64` matrix with the operations the rest
//! of the library needs: matmul, transpose, column ops ([`dense`]);
//! Householder thin-QR ([`qr`]); symmetric eigendecomposition — cyclic
//! Jacobi for dense matrices and implicit-shift QL for the tridiagonal
//! matrices produced by Lanczos ([`eigh`]).
//!
//! Sizes here are "small": dense paths are used for oracles, for the
//! (k+p)-sized cores of randomized SVD, and for PJRT tile staging. The
//! scalable path is `crate::sparse`.

pub mod dense;
pub mod eigh;
pub mod qr;

pub use dense::Mat;
