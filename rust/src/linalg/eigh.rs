//! Symmetric eigendecomposition.
//!
//! Two solvers:
//! * [`jacobi_eigh`] — cyclic Jacobi for small dense symmetric matrices
//!   (oracles, RSVD cores). Robust, O(n^3) with a modest constant.
//! * [`tridiag_eigh`] — implicit-shift QL for symmetric tridiagonal
//!   matrices (the Lanczos inner solve); classic `tql2` algorithm.

use super::dense::Mat;

/// Cyclic Jacobi. Returns `(eigenvalues, eigenvectors)` with eigenvalues
/// sorted **descending** and eigenvectors as the *columns* of the returned
/// matrix (column i pairs with eigenvalue i).
pub fn jacobi_eigh(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "eigh needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::eye(n);

    for _sweep in 0..100 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // Accumulate rotations.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut lam: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // Sort descending, permuting eigenvector columns alongside.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| lam[j].partial_cmp(&lam[i]).unwrap());
    let sorted_lam: Vec<f64> = order.iter().map(|&i| lam[i]).collect();
    let mut sorted_v = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        let col = v.col(oldj);
        sorted_v.set_col(newj, &col);
    }
    lam = sorted_lam;
    (lam, sorted_v)
}

/// Implicit-shift QL for a symmetric tridiagonal matrix given by its
/// diagonal `d` (length n) and sub-diagonal `e` (length n-1).
/// Returns `(eigenvalues desc, eigenvectors as columns)`.
pub fn tridiag_eigh(diag: &[f64], sub: &[f64]) -> (Vec<f64>, Mat) {
    let n = diag.len();
    assert_eq!(sub.len(), n.saturating_sub(1));
    if n == 0 {
        return (Vec::new(), Mat::zeros(0, 0));
    }
    let mut d = diag.to_vec();
    let mut e = vec![0.0; n];
    e[..n - 1].copy_from_slice(sub);
    let mut z = Mat::eye(n);

    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find small off-diagonal to split.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 100, "tridiag_eigh failed to converge");
            // Form shift.
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = (g * g + 1.0).sqrt();
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = (f * f + g * g).sqrt();
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                // Accumulate eigenvectors.
                for k in 0..n {
                    f = z[(k, i + 1)];
                    z[(k, i + 1)] = s * z[(k, i)] + c * f;
                    z[(k, i)] = c * z[(k, i)] - s * f;
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }

    // Sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[j].partial_cmp(&d[i]).unwrap());
    let lam: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut v = Mat::zeros(n, n);
    for (newj, &oldj) in order.iter().enumerate() {
        let col = z.col(oldj);
        v.set_col(newj, &col);
    }
    (lam, v)
}

/// Spectral norm of a small dense symmetric matrix (max |eigenvalue|).
pub fn dense_spectral_norm(a: &Mat) -> f64 {
    let (lam, _) = jacobi_eigh(a);
    lam.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen::sym_contraction;
    use crate::testing::prop::{check, forall};
    use crate::util::rng::Rng;

    fn reconstruct(lam: &[f64], v: &Mat) -> Mat {
        // V diag(lam) V^T
        let n = v.rows;
        let mut vd = v.clone();
        for j in 0..lam.len() {
            for i in 0..n {
                vd[(i, j)] *= lam[j];
            }
        }
        vd.matmul(&v.transpose())
    }

    #[test]
    fn jacobi_known_2x2() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (lam, v) = jacobi_eigh(&a);
        assert!((lam[0] - 3.0).abs() < 1e-12);
        assert!((lam[1] - 1.0).abs() < 1e-12);
        assert!(reconstruct(&lam, &v).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn jacobi_reconstruction_property() {
        forall(
            21,
            10,
            |r| {
                let n = 2 + r.below(9);
                let data = sym_contraction(r, n);
                Mat::from_vec(n, n, data)
            },
            |a| {
                let (lam, v) = jacobi_eigh(a);
                let rec = reconstruct(&lam, &v);
                check(rec.max_abs_diff(a) < 1e-10, format!("err {}", rec.max_abs_diff(a)))?;
                // Descending.
                for w in lam.windows(2) {
                    check(w[0] >= w[1] - 1e-12, "not sorted descending")?;
                }
                // Orthonormal columns.
                let g = v.tmatmul(&v.clone());
                for i in 0..g.rows {
                    for j in 0..g.cols {
                        let want = if i == j { 1.0 } else { 0.0 };
                        check((g[(i, j)] - want).abs() < 1e-10, "V not orthonormal")?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tridiag_matches_jacobi() {
        forall(
            22,
            10,
            |r| {
                let n = 2 + r.below(12);
                let d: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                let e: Vec<f64> = (0..n - 1).map(|_| r.normal()).collect();
                (d, e)
            },
            |(d, e)| {
                let n = d.len();
                let mut full = Mat::zeros(n, n);
                for i in 0..n {
                    full[(i, i)] = d[i];
                }
                for i in 0..n - 1 {
                    full[(i, i + 1)] = e[i];
                    full[(i + 1, i)] = e[i];
                }
                let (lam_t, v_t) = tridiag_eigh(d, e);
                let (lam_j, _) = jacobi_eigh(&full);
                for (a, b) in lam_t.iter().zip(&lam_j) {
                    check((a - b).abs() < 1e-9, format!("eval mismatch {a} vs {b}"))?;
                }
                let rec = reconstruct(&lam_t, &v_t);
                check(rec.max_abs_diff(&full) < 1e-9, "tridiag reconstruction")?;
                Ok(())
            },
        );
    }

    #[test]
    fn tridiag_diagonal_only() {
        let (lam, _) = tridiag_eigh(&[3.0, -1.0, 2.0], &[0.0, 0.0]);
        assert_eq!(lam, vec![3.0, 2.0, -1.0]);
    }

    #[test]
    fn tridiag_empty_and_single() {
        let (lam, _) = tridiag_eigh(&[], &[]);
        assert!(lam.is_empty());
        let (lam, v) = tridiag_eigh(&[5.0], &[]);
        assert_eq!(lam, vec![5.0]);
        assert_eq!(v[(0, 0)], 1.0);
    }

    #[test]
    fn spectral_norm_of_contraction_at_most_one() {
        let mut rng = Rng::new(23);
        let n = 8;
        let a = Mat::from_vec(n, n, sym_contraction(&mut rng, n));
        assert!(dense_spectral_norm(&a) <= 1.0 + 1e-9);
    }
}
