//! Householder thin QR, plus iterated Gram–Schmidt re-orthonormalization.
//!
//! Used by simultaneous iteration and randomized SVD's range finder
//! (Lanczos keeps its own vector-at-a-time reorthogonalization in
//! `crate::eigen::lanczos`). The Gram–Schmidt orthonormalizer is
//! column-dot-parallel over [`crate::par`]'s persistent pool and
//! bitwise thread-count-independent.

use super::dense::Mat;
use crate::par::{self, ExecPolicy, Workspace};

/// Thin QR of an `m x n` matrix (`m >= n`): returns `Q` (`m x n`, columns
/// orthonormal) and `R` (`n x n`, upper triangular).
pub fn thin_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin_qr needs m >= n (got {m} x {n})");
    // Work on the transpose so columns are contiguous.
    let mut at = a.transpose(); // n x m, row j = column j of a
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors
    let mut r = Mat::zeros(n, n);

    for j in 0..n {
        // Apply previous reflectors to column j.
        // (we apply lazily: each reflector v_k zeroes below-diagonal of col k)
        // Column j currently holds a_j with reflectors 0..j applied.
        // Compute Householder vector on subvector [j..m].
        let col = at.row_mut(j);
        let norm_x: f64 = col[j..].iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm_x < 1e-300 {
            // Zero column: R entry 0, identity reflector.
            vs.push(vec![0.0; m - j]);
            r[(j, j)] = 0.0;
            continue;
        }
        let alpha = if col[j] >= 0.0 { -norm_x } else { norm_x };
        let mut v: Vec<f64> = col[j..].to_vec();
        v[0] -= alpha;
        let vnorm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if vnorm > 1e-300 {
            for x in v.iter_mut() {
                *x /= vnorm;
            }
        }
        // Applying the reflector to column j itself gives alpha * e1 by
        // construction — write that directly.
        r[(j, j)] = alpha;
        col[j] = alpha;
        for t in col[j + 1..].iter_mut() {
            *t = 0.0;
        }
        // Apply the reflector to the remaining columns and record R.
        for jj in (j + 1)..n {
            let cjj = at.row_mut(jj);
            let dot: f64 = v.iter().zip(&cjj[j..]).map(|(a, b)| a * b).sum();
            for (t, rv) in cjj[j..].iter_mut().zip(v.iter()) {
                *t -= 2.0 * dot * rv;
            }
        }
        vs.push(v);
    }
    // R is the upper triangle of the fully transformed columns.
    for j in 0..n {
        for i in 0..=j {
            r[(i, j)] = at[(j, i)];
        }
    }

    // Build thin Q by applying reflectors to the first n columns of I.
    let mut qt = Mat::zeros(n, m); // row j = column j of Q
    for j in 0..n {
        qt[(j, j)] = 1.0;
    }
    for j in 0..n {
        let ej = qt.row_mut(j);
        // Apply H_{n-1} ... H_0 in reverse to e_j.
        for (k, v) in vs.iter().enumerate().rev() {
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            let dot: f64 = v.iter().zip(&ej[k..]).map(|(a, b)| a * b).sum();
            for (t, rv) in ej[k..].iter_mut().zip(v.iter()) {
                *t -= 2.0 * dot * rv;
            }
        }
    }
    (qt.transpose(), r)
}

/// Orthonormalize the columns of `a` in place (serial wrapper over
/// [`mgs_orthonormalize_with`]). Returns the rank found (columns with
/// norm below `tol` are zeroed and not counted).
pub fn mgs_orthonormalize(a: &mut Mat, tol: f64) -> usize {
    mgs_orthonormalize_with(a, tol, &ExecPolicy::serial())
}

/// [`mgs_orthonormalize_ws`] with a throwaway workspace.
pub fn mgs_orthonormalize_with(a: &mut Mat, tol: f64, exec: &ExecPolicy) -> usize {
    let mut ws = Workspace::new();
    mgs_orthonormalize_ws(a, tol, exec, &mut ws)
}

/// Column-parallel iterated Gram–Schmidt (CGS2, "twice is enough"):
/// for each column, two rounds of (project against all previous columns,
/// subtract), then normalize. The per-column work fans out over `exec`'s
/// pool two ways — the previous-column dots (one serial full-length dot
/// per task, so scheduling cannot touch its bits) and the element-wise
/// subtraction (fixed previous-column order per element) — making the
/// result **bitwise identical at any thread count**. Works on the
/// transpose internally so columns are contiguous; scratch comes from
/// `ws`, so iteration loops (simultaneous iteration, RSVD powers)
/// re-orthonormalize with zero steady-state allocations.
pub fn mgs_orthonormalize_ws(
    a: &mut Mat,
    tol: f64,
    exec: &ExecPolicy,
    ws: &mut Workspace,
) -> usize {
    let (m, n) = (a.rows, a.cols);
    if n == 0 || m == 0 {
        return 0;
    }
    let _span = crate::obs::span(&crate::obs::ORTHO);
    let mut at = ws.take_mat(n, m); // row j = column j of a
    a.transpose_into(&mut at);
    let mut dots = ws.take(n);
    let mut rank = 0;
    for j in 0..n {
        let (head, tail) = at.data.split_at_mut(j * m);
        let colj = &mut tail[..m];
        for _round in 0..2 {
            if j == 0 {
                break;
            }
            // Fan out the j previous-column dots q_k · a_j.
            {
                let colj = &*colj;
                let ranges = par::even_ranges(j, exec.chunks(j));
                exec.for_chunks(&ranges, &mut dots[..j], 1, |_, ks, out| {
                    for (slot, k) in out.iter_mut().zip(ks) {
                        let qk = &head[k * m..(k + 1) * m];
                        *slot = qk.iter().zip(colj).map(|(x, y)| x * y).sum();
                    }
                });
            }
            // a_j -= Σ_k dots_k q_k, element-wise over rows, k ascending.
            let dj = &dots[..j];
            let ranges = par::even_ranges(m, exec.chunks(m));
            exec.for_chunks(&ranges, colj, 1, |_, is, out| {
                for (slot, i) in out.iter_mut().zip(is) {
                    let mut acc = *slot;
                    for (k, dk) in dj.iter().enumerate() {
                        acc -= dk * head[k * m + i];
                    }
                    *slot = acc;
                }
            });
        }
        let norm: f64 = colj.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > tol {
            for x in colj.iter_mut() {
                *x /= norm;
            }
            rank += 1;
        } else {
            colj.fill(0.0);
        }
    }
    at.transpose_into(a);
    ws.give(dots);
    ws.give_mat(at);
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, forall};
    use crate::util::rng::Rng;

    fn assert_orthonormal(q: &Mat, tol: f64) {
        let g = q.tmatmul(q);
        for i in 0..g.rows {
            for j in 0..g.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g[(i, j)] - want).abs() < tol,
                    "gram[{i},{j}] = {}",
                    g[(i, j)]
                );
            }
        }
    }

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        forall(
            11,
            12,
            |r| {
                let m = 4 + r.below(8);
                let n = 1 + r.below(m.min(5));
                Mat::randn(r, m, n)
            },
            |a| {
                let (q, r) = thin_qr(a);
                let qr = q.matmul(&r);
                check(qr.max_abs_diff(a) < 1e-10, format!("A != QR, err {}", qr.max_abs_diff(a)))?;
                let g = q.tmatmul(&q);
                for i in 0..g.rows {
                    for j in 0..g.cols {
                        let want = if i == j { 1.0 } else { 0.0 };
                        check((g[(i, j)] - want).abs() < 1e-10, "Q not orthonormal")?;
                    }
                }
                // R upper triangular
                for i in 0..r.rows {
                    for j in 0..i {
                        check(r[(i, j)].abs() < 1e-12, "R not upper triangular")?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn qr_rank_deficient_column() {
        let mut rng = Rng::new(12);
        let mut a = Mat::randn(&mut rng, 6, 3);
        // Make col 1 a copy of col 0 (rank deficiency).
        let c0 = a.col(0);
        a.set_col(1, &c0);
        let (q, r) = thin_qr(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-10);
        assert!(r[(1, 1)].abs() < 1e-10, "R[1,1] should be ~0");
    }

    #[test]
    fn mgs_orthonormalizes_full_rank() {
        let mut rng = Rng::new(13);
        let mut a = Mat::randn(&mut rng, 10, 4);
        let rank = mgs_orthonormalize(&mut a, 1e-12);
        assert_eq!(rank, 4);
        assert_orthonormal(&a, 1e-10);
    }

    #[test]
    fn mgs_detects_rank_deficiency() {
        let mut rng = Rng::new(14);
        let mut a = Mat::randn(&mut rng, 8, 3);
        let c0 = a.col(0);
        a.set_col(2, &c0);
        let rank = mgs_orthonormalize(&mut a, 1e-8);
        assert_eq!(rank, 2);
    }
}
