//! Householder thin QR, plus modified Gram–Schmidt re-orthonormalization.
//!
//! Used by the Lanczos full-reorthogonalization step, simultaneous
//! iteration, and randomized SVD's range finder.

use super::dense::Mat;

/// Thin QR of an `m x n` matrix (`m >= n`): returns `Q` (`m x n`, columns
/// orthonormal) and `R` (`n x n`, upper triangular).
pub fn thin_qr(a: &Mat) -> (Mat, Mat) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin_qr needs m >= n (got {m} x {n})");
    // Work on the transpose so columns are contiguous.
    let mut at = a.transpose(); // n x m, row j = column j of a
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors
    let mut r = Mat::zeros(n, n);

    for j in 0..n {
        // Apply previous reflectors to column j.
        // (we apply lazily: each reflector v_k zeroes below-diagonal of col k)
        // Column j currently holds a_j with reflectors 0..j applied.
        // Compute Householder vector on subvector [j..m].
        let col = at.row_mut(j);
        let norm_x: f64 = col[j..].iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm_x < 1e-300 {
            // Zero column: R entry 0, identity reflector.
            vs.push(vec![0.0; m - j]);
            r[(j, j)] = 0.0;
            continue;
        }
        let alpha = if col[j] >= 0.0 { -norm_x } else { norm_x };
        let mut v: Vec<f64> = col[j..].to_vec();
        v[0] -= alpha;
        let vnorm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if vnorm > 1e-300 {
            for x in v.iter_mut() {
                *x /= vnorm;
            }
        }
        // Applying the reflector to column j itself gives alpha * e1 by
        // construction — write that directly.
        r[(j, j)] = alpha;
        col[j] = alpha;
        for t in col[j + 1..].iter_mut() {
            *t = 0.0;
        }
        // Apply the reflector to the remaining columns and record R.
        for jj in (j + 1)..n {
            let cjj = at.row_mut(jj);
            let dot: f64 = v.iter().zip(&cjj[j..]).map(|(a, b)| a * b).sum();
            for (t, rv) in cjj[j..].iter_mut().zip(v.iter()) {
                *t -= 2.0 * dot * rv;
            }
        }
        vs.push(v);
    }
    // R is the upper triangle of the fully transformed columns.
    for j in 0..n {
        for i in 0..=j {
            r[(i, j)] = at[(j, i)];
        }
    }

    // Build thin Q by applying reflectors to the first n columns of I.
    let mut qt = Mat::zeros(n, m); // row j = column j of Q
    for j in 0..n {
        qt[(j, j)] = 1.0;
    }
    for j in 0..n {
        let ej = qt.row_mut(j);
        // Apply H_{n-1} ... H_0 in reverse to e_j.
        for (k, v) in vs.iter().enumerate().rev() {
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            let dot: f64 = v.iter().zip(&ej[k..]).map(|(a, b)| a * b).sum();
            for (t, rv) in ej[k..].iter_mut().zip(v.iter()) {
                *t -= 2.0 * dot * rv;
            }
        }
    }
    (qt.transpose(), r)
}

/// Orthonormalize the columns of `a` in place via two rounds of modified
/// Gram–Schmidt (twice-is-enough). Returns the rank found (columns with
/// norm below `tol` are zeroed and not counted).
pub fn mgs_orthonormalize(a: &mut Mat, tol: f64) -> usize {
    let n = a.cols;
    let mut rank = 0;
    for _round in 0..2 {
        rank = 0;
        for j in 0..n {
            let mut col = a.col(j);
            for k in 0..j {
                let ck = a.col(k);
                let dot: f64 = col.iter().zip(&ck).map(|(x, y)| x * y).sum();
                for (x, y) in col.iter_mut().zip(&ck) {
                    *x -= dot * y;
                }
            }
            let norm: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > tol {
                for x in col.iter_mut() {
                    *x /= norm;
                }
                rank += 1;
            } else {
                col.iter_mut().for_each(|x| *x = 0.0);
            }
            a.set_col(j, &col);
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, forall};
    use crate::util::rng::Rng;

    fn assert_orthonormal(q: &Mat, tol: f64) {
        let g = q.tmatmul(q);
        for i in 0..g.rows {
            for j in 0..g.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (g[(i, j)] - want).abs() < tol,
                    "gram[{i},{j}] = {}",
                    g[(i, j)]
                );
            }
        }
    }

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        forall(
            11,
            12,
            |r| {
                let m = 4 + r.below(8);
                let n = 1 + r.below(m.min(5));
                Mat::randn(r, m, n)
            },
            |a| {
                let (q, r) = thin_qr(a);
                let qr = q.matmul(&r);
                check(qr.max_abs_diff(a) < 1e-10, format!("A != QR, err {}", qr.max_abs_diff(a)))?;
                let g = q.tmatmul(&q);
                for i in 0..g.rows {
                    for j in 0..g.cols {
                        let want = if i == j { 1.0 } else { 0.0 };
                        check((g[(i, j)] - want).abs() < 1e-10, "Q not orthonormal")?;
                    }
                }
                // R upper triangular
                for i in 0..r.rows {
                    for j in 0..i {
                        check(r[(i, j)].abs() < 1e-12, "R not upper triangular")?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn qr_rank_deficient_column() {
        let mut rng = Rng::new(12);
        let mut a = Mat::randn(&mut rng, 6, 3);
        // Make col 1 a copy of col 0 (rank deficiency).
        let c0 = a.col(0);
        a.set_col(1, &c0);
        let (q, r) = thin_qr(&a);
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-10);
        assert!(r[(1, 1)].abs() < 1e-10, "R[1,1] should be ~0");
    }

    #[test]
    fn mgs_orthonormalizes_full_rank() {
        let mut rng = Rng::new(13);
        let mut a = Mat::randn(&mut rng, 10, 4);
        let rank = mgs_orthonormalize(&mut a, 1e-12);
        assert_eq!(rank, 4);
        assert_orthonormal(&a, 1e-10);
    }

    #[test]
    fn mgs_detects_rank_deficiency() {
        let mut rng = Rng::new(14);
        let mut a = Mat::randn(&mut rng, 8, 3);
        let c0 = a.col(0);
        a.set_col(2, &c0);
        let rank = mgs_orthonormalize(&mut a, 1e-8);
        assert_eq!(rank, 2);
    }
}
