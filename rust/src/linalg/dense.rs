//! Row-major dense matrix.

use crate::util::rng::Rng;

/// Row-major `rows x cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// i.i.d. standard normal entries.
    pub fn randn(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal()).collect(),
        }
    }

    /// Rademacher ±1/sqrt(cols') JL projection block is built in
    /// `crate::embed::omega`; this is plain ±1.
    pub fn rademacher(rng: &mut Rng, rows: usize, cols: usize) -> Self {
        Mat {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.rademacher()).collect(),
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut t);
        t
    }

    /// Transpose into a preallocated `cols × rows` matrix (the
    /// allocation-free form for iteration loops).
    pub fn transpose_into(&self, t: &mut Mat) {
        assert_eq!((t.rows, t.cols), (self.cols, self.rows), "transpose_into shape");
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
    }

    /// `self @ other`, blocked i-k-j loop order (cache friendly row-major).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                for (j, &bkj) in brow.iter().enumerate() {
                    orow[j] += aik * bkj;
                }
            }
        }
        out
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn tmatmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "tmatmul shape mismatch");
        let mut out = Mat::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = other.row(k);
            for (i, &aki) in arow.iter().enumerate() {
                if aki == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                for (j, &bkj) in brow.iter().enumerate() {
                    orow[j] += aki * bkj;
                }
            }
        }
        out
    }

    pub fn scale(&mut self, s: f64) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// `self += s * other`.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn col_norm(&self, j: usize) -> f64 {
        (0..self.rows).map(|i| self[(i, j)] * self[(i, j)]).sum::<f64>().sqrt()
    }

    pub fn row_norm(&self, i: usize) -> f64 {
        self.row(i).iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Dot product of rows i and j.
    pub fn row_dot(&self, i: usize, j: usize) -> f64 {
        self.row(i).iter().zip(self.row(j)).map(|(a, b)| a * b).sum()
    }

    /// Euclidean distance between rows of two (possibly different) matrices.
    pub fn row_dist(&self, i: usize, other: &Mat, j: usize) -> f64 {
        assert_eq!(self.cols, other.cols);
        self.row(i)
            .iter()
            .zip(other.row(j))
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }

    /// Normalized correlation between rows i, j (0 when either is ~0).
    pub fn row_corr(&self, i: usize, j: usize) -> f64 {
        let ni = self.row_norm(i);
        let nj = self.row_norm(j);
        if ni < 1e-300 || nj < 1e-300 {
            return 0.0;
        }
        self.row_dot(i, j) / (ni * nj)
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Take a subset of columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        let mut out = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        out
    }

    /// Symmetric check (tests).
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in 0..i {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{all_close, check, forall};

    #[test]
    fn matmul_small_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(&mut rng, 7, 5);
        let c = a.matmul(&Mat::eye(5));
        assert!(a.max_abs_diff(&c) < 1e-14);
    }

    #[test]
    fn tmatmul_matches_explicit_transpose() {
        forall(
            2,
            16,
            |r| (Mat::randn(r, 6, 4), Mat::randn(r, 6, 3)),
            |(a, b)| {
                let got = a.tmatmul(b);
                let want = a.transpose().matmul(b);
                all_close(&got.data, &want.data, 1e-12)
            },
        );
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(&mut rng, 5, 8);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn axpy_and_sub() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(&mut rng, 3, 3);
        let b = Mat::randn(&mut rng, 3, 3);
        let mut c = a.clone();
        c.axpy(-1.0, &b);
        assert!(c.max_abs_diff(&a.sub(&b)) < 1e-15);
    }

    #[test]
    fn row_corr_properties() {
        forall(
            5,
            32,
            |r| Mat::randn(r, 4, 6),
            |m| {
                for i in 0..4 {
                    check(
                        (m.row_corr(i, i) - 1.0).abs() < 1e-12,
                        format!("self-corr row {i}"),
                    )?;
                    for j in 0..4 {
                        let c = m.row_corr(i, j);
                        check(c.abs() <= 1.0 + 1e-12, format!("|corr| <= 1, got {c}"))?;
                        check(
                            (c - m.row_corr(j, i)).abs() < 1e-12,
                            "corr symmetric",
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn row_corr_zero_row_is_zero() {
        let m = Mat::from_rows(&[&[0.0, 0.0], &[1.0, 2.0]]);
        assert_eq!(m.row_corr(0, 1), 0.0);
    }

    #[test]
    fn row_dist_matches_norm_of_diff() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 2.0]]);
        let b = Mat::from_rows(&[&[0.0, 0.0, 0.0]]);
        assert!((a.row_dist(0, &b, 0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn take_cols_prefix() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let b = a.take_cols(2);
        assert_eq!(b.data, vec![1.0, 2.0, 4.0, 5.0]);
    }
}
