//! # cse — Compressive Spectral Embedding
//!
//! A production-grade reproduction of *"Compressive spectral embedding:
//! sidestepping the SVD"* (Ramasamy & Madhow, NIPS 2015): compute an
//! `O(log n)`-dimensional embedding that approximates pairwise ℓ₂
//! geometry of the SVD-based spectral embedding
//! `E = [f(λ₁)v₁ … f(λₙ)vₙ]` — in time `O((T + n) log n)`, independent of
//! how many singular vectors the weighing function `f` touches.
//!
//! ## Layers
//! * **Rust (this crate)** — the scalable runtime: sparse operators,
//!   the FastEmbed driver, eigensolver baselines, K-means/modularity,
//!   the [`par`] execution layer (a dependency-free persistent worker
//!   pool + workspace arena that every compute hot path runs on,
//!   deterministically and without steady-state allocations),
//!   the column-shard coordinator and the similarity-query service, the
//!   [`index`] ANN layer (SimHash LSH + exact baseline) that makes top-k
//!   serving sublinear, the [`obs`] observability layer (atomic log-bucket
//!   histograms, tracing spans with Chrome `trace_event` export, and
//!   per-stage profiling through pool, kernels, and serving), and a PJRT
//!   runtime that executes JAX/Pallas-authored HLO artifacts for dense
//!   tiles (`pjrt` feature).
//! * **Python (`python/compile`)** — build-time only: Pallas kernels
//!   (L1) and JAX graphs (L2), AOT-lowered to `artifacts/*.hlo.txt`.
//!
//! ## Quickstart
//! ```no_run
//! use cse::embed::{FastEmbed, Params};
//! use cse::funcs::SpectralFn;
//! use cse::sparse::{gen, graph};
//! use cse::util::rng::Rng;
//!
//! let mut rng = Rng::new(0);
//! let g = gen::sbm_by_degree(&mut rng, 2000, 20, 5.0, 1.0);
//! let s = graph::normalized_adjacency(&g.adj);
//! let params = Params { d: 48, order: 120, cascade: 2, ..Params::default() };
//! let emb = FastEmbed::new(params).embed(&s, &SpectralFn::Step { c: 0.7 }, &mut rng);
//! // rows of `emb.e` now approximate rows of [I(λ≥0.7)·v₁ … ] up to JL distortion
//! ```
//!
//! See `examples/` for end-to-end drivers and `rust/benches/` for the
//! harness regenerating every figure/table in the paper.

pub mod cluster;
pub mod coordinator;
pub mod eigen;
pub mod embed;
pub mod fault;
pub mod funcs;
pub mod index;
pub mod linalg;
pub mod obs;
pub mod par;
pub mod poly;
pub mod runtime;
pub mod sparse;
pub mod testing;
pub mod util;
