//! Typed job failures surfaced by the coordinator and serving layers.

use std::fmt;

/// Why an embedding job or a query batch could not produce a result.
///
/// Everything here is *recoverable at the process level*: the pool and
/// coordinator stay reusable after any of these, and the CLI renders
/// them and exits non-zero instead of aborting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobError {
    /// A shard kept panicking past its retry budget.
    ShardFailed { shard: usize, attempts: usize, reason: String },
    /// A shard's recurrence produced non-finite values past its retry
    /// budget; `stage` is the 0-based cascade stage that blew up.
    NumericalBlowup { shard: usize, stage: usize, stages: usize },
    /// The job ran past its deadline; `done`/`total` report partial
    /// progress (shards for embedding jobs, queries for batches).
    DeadlineExceeded { done: usize, total: usize, elapsed_ms: u64 },
    /// The input failed validation before any compute started.
    InvalidInput(String),
    /// An internal invariant broke (a bug, not an input problem).
    Internal(String),
}

impl fmt::Display for JobError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobError::ShardFailed { shard, attempts, reason } => {
                write!(f, "shard {shard} failed after {attempts} attempt(s): {reason}")
            }
            JobError::NumericalBlowup { shard, stage, stages } => write!(
                f,
                "numerical blow-up in cascade stage {}/{stages} of shard {shard}: \
                 recurrence output is non-finite",
                stage + 1
            ),
            JobError::DeadlineExceeded { done, total, elapsed_ms } => write!(
                f,
                "deadline exceeded after {elapsed_ms} ms with {done}/{total} units complete"
            ),
            JobError::InvalidInput(msg) => write!(f, "invalid input: {msg}"),
            JobError::Internal(msg) => write!(f, "internal error: {msg}"),
        }
    }
}

impl std::error::Error for JobError {}

impl From<crate::sparse::csr::CsrError> for JobError {
    fn from(e: crate::sparse::csr::CsrError) -> Self {
        JobError::InvalidInput(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_offending_stage() {
        let e = JobError::NumericalBlowup { shard: 3, stage: 1, stages: 2 };
        let msg = e.to_string();
        assert!(msg.contains("stage 2/2"), "got {msg:?}");
        assert!(msg.contains("shard 3"), "got {msg:?}");
    }

    #[test]
    fn display_reports_partial_progress() {
        let e = JobError::DeadlineExceeded { done: 4, total: 9, elapsed_ms: 17 };
        let msg = e.to_string();
        assert!(msg.contains("4/9") && msg.contains("17 ms"), "got {msg:?}");
    }
}
