//! Atomic runtime metrics exported by the coordinator and the service.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Counters shared across workers. All methods are lock-free.
#[derive(Default)]
pub struct Metrics {
    pub matvecs: AtomicUsize,
    /// Kernel threads (`ExecPolicy`) the last job ran with — a gauge,
    /// recorded so serving/bench reports can attribute throughput.
    pub threads: AtomicUsize,
    pub shards_done: AtomicUsize,
    pub shards_total: AtomicUsize,
    pub queries: AtomicUsize,
    /// Cumulative query latency in nanoseconds.
    pub query_ns: AtomicU64,
    pub rows_flushed: AtomicUsize,
    /// Top-k queries answered (exact or indexed).
    pub topk_queries: AtomicUsize,
    /// Cumulative candidate rows exactly scored across top-k queries —
    /// with an ANN index this is the per-query scan cost the index saved
    /// the service from paying in full.
    pub candidates_scanned: AtomicUsize,
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Snapshot {
    pub matvecs: usize,
    pub threads: usize,
    pub shards_done: usize,
    pub shards_total: usize,
    pub queries: usize,
    pub query_ns: u64,
    pub rows_flushed: usize,
    pub topk_queries: usize,
    pub candidates_scanned: usize,
}

impl Metrics {
    pub fn add_matvecs(&self, n: usize) {
        self.matvecs.fetch_add(n, Ordering::Relaxed);
    }

    /// Record the kernel thread count of the job being executed.
    pub fn set_threads(&self, n: usize) {
        self.threads.store(n, Ordering::Relaxed);
    }

    pub fn shard_done(&self) {
        self.shards_done.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_query(&self, ns: u64) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.query_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record one answered top-k query and its candidate-set size.
    pub fn record_topk(&self, candidates: usize) {
        self.topk_queries.fetch_add(1, Ordering::Relaxed);
        self.candidates_scanned.fetch_add(candidates, Ordering::Relaxed);
    }

    /// Mean candidate rows scored per top-k query (NaN when none ran).
    pub fn mean_candidates(&self) -> f64 {
        let q = self.topk_queries.load(Ordering::Relaxed);
        if q == 0 {
            return f64::NAN;
        }
        self.candidates_scanned.load(Ordering::Relaxed) as f64 / q as f64
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            matvecs: self.matvecs.load(Ordering::Relaxed),
            threads: self.threads.load(Ordering::Relaxed),
            shards_done: self.shards_done.load(Ordering::Relaxed),
            shards_total: self.shards_total.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            query_ns: self.query_ns.load(Ordering::Relaxed),
            rows_flushed: self.rows_flushed.load(Ordering::Relaxed),
            topk_queries: self.topk_queries.load(Ordering::Relaxed),
            candidates_scanned: self.candidates_scanned.load(Ordering::Relaxed),
        }
    }

    /// Mean query latency in microseconds (NaN when no queries).
    pub fn mean_query_us(&self) -> f64 {
        let q = self.queries.load(Ordering::Relaxed);
        if q == 0 {
            return f64::NAN;
        }
        self.query_ns.load(Ordering::Relaxed) as f64 / q as f64 / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add_matvecs(10);
        m.add_matvecs(5);
        m.shard_done();
        m.record_query(2_000);
        m.record_query(4_000);
        let s = m.snapshot();
        assert_eq!(s.matvecs, 15);
        assert_eq!(s.shards_done, 1);
        assert_eq!(s.queries, 2);
        assert!((m.mean_query_us() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn topk_candidate_accounting() {
        let m = Metrics::default();
        assert!(m.mean_candidates().is_nan());
        m.record_topk(100);
        m.record_topk(50);
        let s = m.snapshot();
        assert_eq!(s.topk_queries, 2);
        assert_eq!(s.candidates_scanned, 150);
        assert!((m.mean_candidates() - 75.0).abs() < 1e-12);
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.add_matvecs(1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().matvecs, 4000);
    }
}
