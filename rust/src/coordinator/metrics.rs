//! Atomic runtime metrics exported by the coordinator and the service.
//!
//! Flat counters live beside two [`Histogram`]s (query latency, top-k
//! candidate-set size) so the service can report real percentiles —
//! p50/p99 exact on the log-bucket grid — instead of deriving everything
//! from a cumulative-sum mean (which is what the pre-obs `query_ns`
//! field forced). [`Snapshot`] stays a `Copy` bag of integers for cheap
//! delta arithmetic; histogram windows use
//! [`Histogram::snapshot`]/[`crate::obs::HistSnapshot::sub`] instead.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::obs::Histogram;

/// Counters shared across workers. All methods are lock-free.
#[derive(Default)]
pub struct Metrics {
    pub matvecs: AtomicUsize,
    /// Kernel threads (`ExecPolicy`) the last job ran with — a gauge,
    /// recorded so serving/bench reports can attribute throughput.
    pub threads: AtomicUsize,
    pub shards_done: AtomicUsize,
    pub shards_total: AtomicUsize,
    pub queries: AtomicUsize,
    /// Per-query latency distribution in nanoseconds — replaces the old
    /// cumulative `query_ns` sum (the exact sum survives as
    /// `query_hist.sum()`, so means are unchanged; percentiles are new).
    pub query_hist: Histogram,
    pub rows_flushed: AtomicUsize,
    /// Top-k queries answered (exact or indexed).
    pub topk_queries: AtomicUsize,
    /// Cumulative candidate rows exactly scored across top-k queries —
    /// with an ANN index this is the per-query scan cost the index saved
    /// the service from paying in full.
    pub candidates_scanned: AtomicUsize,
    /// Per-query candidate-set-size distribution (same events as
    /// `candidates_scanned`, but as a histogram: the tail matters — one
    /// bucket collision can cost 100× the mean scan).
    pub candidates_hist: Histogram,
    /// Shard re-executions after a caught panic or blow-up (the retry
    /// path of the fault-tolerant scheduler).
    pub shard_retries: AtomicUsize,
    /// Jobs/batches aborted because their deadline passed.
    pub deadline_aborts: AtomicUsize,
    /// Top-k queries that fell back from a failed/empty ANN probe to
    /// the exact scanner.
    pub fallback_exact: AtomicUsize,
    /// Top-k queries rejected by load shedding (p99 over threshold).
    pub queries_shed: AtomicUsize,
}

/// Point-in-time snapshot for reporting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Snapshot {
    pub matvecs: usize,
    pub threads: usize,
    pub shards_done: usize,
    pub shards_total: usize,
    pub queries: usize,
    /// Summed query latency in ns (`query_hist.sum()`).
    pub query_ns: u64,
    pub rows_flushed: usize,
    pub topk_queries: usize,
    pub candidates_scanned: usize,
    pub shard_retries: usize,
    pub deadline_aborts: usize,
    pub fallback_exact: usize,
    pub queries_shed: usize,
}

impl Metrics {
    pub fn add_matvecs(&self, n: usize) {
        self.matvecs.fetch_add(n, Ordering::Relaxed);
    }

    /// Record the kernel thread count of the job being executed.
    pub fn set_threads(&self, n: usize) {
        self.threads.store(n, Ordering::Relaxed);
    }

    pub fn shard_done(&self) {
        self.shards_done.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one shard re-execution (caught panic or blow-up).
    pub fn shard_retry(&self) {
        self.shard_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one load-shed (rejected) query.
    pub fn query_shed(&self) {
        self.queries_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_query(&self, ns: u64) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.query_hist.record(ns);
    }

    /// Record one answered top-k query and its candidate-set size.
    pub fn record_topk(&self, candidates: usize) {
        self.topk_queries.fetch_add(1, Ordering::Relaxed);
        self.candidates_scanned.fetch_add(candidates, Ordering::Relaxed);
        self.candidates_hist.record(candidates as u64);
    }

    /// Mean candidate rows scored per top-k query — 0.0 when none ran
    /// (NaN here used to leak into JSON artifacts, which `util/json`
    /// cannot represent).
    pub fn mean_candidates(&self) -> f64 {
        let q = self.topk_queries.load(Ordering::Relaxed);
        if q == 0 {
            return 0.0;
        }
        self.candidates_scanned.load(Ordering::Relaxed) as f64 / q as f64
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            matvecs: self.matvecs.load(Ordering::Relaxed),
            threads: self.threads.load(Ordering::Relaxed),
            shards_done: self.shards_done.load(Ordering::Relaxed),
            shards_total: self.shards_total.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            query_ns: self.query_hist.sum(),
            rows_flushed: self.rows_flushed.load(Ordering::Relaxed),
            topk_queries: self.topk_queries.load(Ordering::Relaxed),
            candidates_scanned: self.candidates_scanned.load(Ordering::Relaxed),
            shard_retries: self.shard_retries.load(Ordering::Relaxed),
            deadline_aborts: self.deadline_aborts.load(Ordering::Relaxed),
            fallback_exact: self.fallback_exact.load(Ordering::Relaxed),
            queries_shed: self.queries_shed.load(Ordering::Relaxed),
        }
    }

    /// Mean query latency in microseconds — 0.0 when no queries ran
    /// (exact: the histogram keeps the full sum).
    pub fn mean_query_us(&self) -> f64 {
        self.query_hist.mean() / 1e3
    }

    /// Query latency percentile in microseconds, exact on the histogram's
    /// log-bucket grid (0.0 when no queries ran).
    pub fn query_percentile_us(&self, p: f64) -> f64 {
        self.query_hist.percentile(p) as f64 / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::default();
        m.add_matvecs(10);
        m.add_matvecs(5);
        m.shard_done();
        m.record_query(2_000);
        m.record_query(4_000);
        m.shard_retry();
        m.query_shed();
        let s = m.snapshot();
        assert_eq!(s.matvecs, 15);
        assert_eq!(s.shards_done, 1);
        assert_eq!(s.queries, 2);
        assert_eq!(s.shard_retries, 1);
        assert_eq!(s.queries_shed, 1);
        assert_eq!(s.deadline_aborts, 0);
        assert_eq!(s.fallback_exact, 0);
        assert_eq!(s.query_ns, 6_000, "histogram keeps the exact sum");
        assert!((m.mean_query_us() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn topk_candidate_accounting() {
        let m = Metrics::default();
        assert_eq!(m.mean_candidates(), 0.0, "no queries → 0, not NaN");
        m.record_topk(100);
        m.record_topk(50);
        let s = m.snapshot();
        assert_eq!(s.topk_queries, 2);
        assert_eq!(s.candidates_scanned, 150);
        assert!((m.mean_candidates() - 75.0).abs() < 1e-12);
        assert_eq!(m.candidates_hist.count(), 2);
        assert_eq!(m.candidates_hist.max(), 100);
    }

    #[test]
    fn idle_metrics_serialize_to_valid_json() {
        // Regression: mean_candidates()/mean_query_us() used to be NaN
        // with zero queries, and util/json writes NaN as the bare token
        // `NaN` — invalid JSON that poisoned every downstream artifact.
        let m = Metrics::default();
        for v in [m.mean_candidates(), m.mean_query_us(), m.query_percentile_us(99.0)] {
            let s = Json::Num(v).to_string();
            assert!(Json::parse(&s).is_ok(), "{s:?} must parse as JSON");
        }
    }

    #[test]
    fn latency_percentiles_come_from_the_histogram() {
        let m = Metrics::default();
        // 90 fast queries (~1µs) and 10 slow ones (~1ms): a mean-derived
        // "percentile" would smear these; the histogram separates them.
        for _ in 0..90 {
            m.record_query(1_000);
        }
        for _ in 0..10 {
            m.record_query(1_000_000);
        }
        let p50 = m.query_percentile_us(50.0);
        let p99 = m.query_percentile_us(99.0);
        assert!(p50 < 3.0, "p50 = {p50} µs should be in the fast bucket");
        assert!(p99 >= 500.0, "p99 = {p99} µs should be in the slow bucket");
        assert_eq!(m.query_hist.max(), 1_000_000);
        let mean_us = m.mean_query_us();
        assert!((mean_us - 100.9).abs() < 1e-9, "mean stays exact: {mean_us}");
    }

    #[test]
    fn concurrent_updates() {
        let m = std::sync::Arc::new(Metrics::default());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.add_matvecs(1);
                        m.record_query(500);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(m.snapshot().matvecs, 4000);
        assert_eq!(m.query_hist.count(), 4000);
        assert_eq!(m.snapshot().query_ns, 4000 * 500);
    }
}
