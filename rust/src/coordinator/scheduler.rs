//! The column-shard scheduler: the paper's "run the 2L matrix-vector
//! chains in parallel across the d starting vectors", implemented as a
//! worker pool over column shards of Ω.
//!
//! Sharding is *exact*: each shard runs the identical recursion on a
//! column subset of Ω, and column chains never interact, so the
//! reassembled embedding is bit-identical to the unsharded driver
//! (property-tested below). Shard width also bounds worker memory:
//! 4 blocks (result + 3 ping-pong) of n × shard_width doubles — which is
//! why `shard_width == 0` derives the width from n, d and a fixed cache
//! budget via [`par::adaptive_shard_width`] instead of a one-size knob.
//!
//! Two parallelism axes compose here: `workers` shard-level threads (this
//! pool) × `job.params.exec.threads` row-parallel threads inside each
//! shard's block products (`crate::par`). Both are deterministic, so any
//! (workers, threads) combination produces the same embedding; keep
//! workers × threads ≤ cores to avoid oversubscription. Wide graphs with
//! few columns want `exec` threads; many-column jobs want workers —
//! and [`Coordinator::new`]`(0)` (the CLI default) picks the split
//! automatically per job via [`auto_split`]: shard workers first (they
//! scale embarrassingly), leftover cores as kernel threads.
//!
//! Each shard worker owns a [`Workspace`], so after its first shard the
//! recursion's steady state performs zero heap allocations (the shard
//! blocks themselves recycle through the same arena).
//!
//! The scheduler is generic over [`Operator`], so shard workers run the
//! same code on any sparse backend — the CLI hands it a
//! `crate::sparse::SparseMat` (CSR or SELL-C-σ, `--format`/`--tune`
//! resolved), and because every backend's products are
//! bitwise-identical, the format choice never shows up in results.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::error::JobError;
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use crate::embed::fastembed::{apply_series_ws, plan_scaled};
use crate::embed::norm::spectral_norm;
use crate::embed::omega::rademacher_omega;
use crate::embed::op::{Operator, ScaledOp};
use crate::embed::Params;
use crate::fault::FaultKind;
use crate::funcs::SpectralFn;
use crate::linalg::Mat;
use crate::par::{self, CancelToken, ExecPolicy, Workspace};
use crate::poly::cascade::CascadePlan;
use crate::util::rng::Rng;

/// Default per-shard retry budget (see [`EmbedJob::max_retries`]).
/// Generous on purpose: retries are cheap (one shard's recurrence), and
/// at the chaos harness's p = 0.3 injection rate the probability of a
/// shard exhausting 8 retries is 0.3⁹ ≈ 2·10⁻⁵.
pub const DEFAULT_MAX_RETRIES: usize = 8;

/// An embedding job specification.
#[derive(Clone, Debug)]
pub struct EmbedJob {
    pub params: Params,
    pub f: SpectralFn,
    /// Column-shard width (starting vectors per work item); `0` picks an
    /// adaptive width from n, d and a cache budget
    /// ([`par::adaptive_shard_width`]). Purely a scheduling knob — any
    /// width yields bit-identical embeddings.
    pub shard_width: usize,
    pub seed: u64,
    /// Let the coordinator pick the kernel thread count from the core
    /// count (`cores / workers`), *replacing* `params.exec`. Off by
    /// default so an explicit `params.exec` — including deliberately
    /// serial kernels — is always respected; the CLI sets this when
    /// `--threads 0`.
    pub auto_threads: bool,
    /// How many times a failed shard (panic or numerical blow-up) is
    /// re-executed before the job fails with [`JobError::ShardFailed`] /
    /// [`JobError::NumericalBlowup`]. Each shard is a pure function of
    /// its Ω column slice, so re-execution is bitwise-safe: the final
    /// embedding is identical whether or not any retry happened.
    pub max_retries: usize,
    /// Wall-clock deadline in milliseconds (`None` = unbounded). The
    /// token is polled at row-block granularity inside the kernels, per
    /// recurrence step, and at shard boundaries; an over-deadline job
    /// returns [`JobError::DeadlineExceeded`] with partial-progress
    /// stats instead of hanging.
    pub deadline_ms: Option<u64>,
    /// Base delay in milliseconds for jittered exponential backoff
    /// between shard retry attempts (`0` — the default — retries
    /// immediately, the pre-backoff behaviour). Attempt `k` sleeps a
    /// duration in `[c/2, c]` where `c = base · 2^min(k−1, 6)`,
    /// jittered by a splitmix64 hash of `(shard, attempt)` — a pure
    /// function, so runs under `--fault-spec` seeds stay exactly
    /// reproducible. Backoff delays scheduling only; the retried
    /// shard's bits are unchanged.
    pub retry_backoff_ms: u64,
}

impl EmbedJob {
    pub fn new(params: Params, f: SpectralFn, seed: u64) -> Self {
        EmbedJob {
            params,
            f,
            shard_width: 0,
            seed,
            auto_threads: false,
            max_retries: DEFAULT_MAX_RETRIES,
            deadline_ms: None,
            retry_backoff_ms: 0,
        }
    }
}

/// Result: the reassembled embedding + execution telemetry.
pub struct JobResult {
    pub e: Mat,
    pub plan: CascadePlan,
    pub norm_estimate: f64,
    pub matvecs: usize,
    pub shards: usize,
    /// Shard workers actually used (after auto-composition).
    pub workers: usize,
    /// Kernel threads per shard actually used (after auto-composition).
    pub threads: usize,
    /// Shard re-executions this job survived (0 on a healthy run).
    pub retries: usize,
}

/// Worker-pool coordinator. `workers` is the shard-level pool size
/// (`0` = auto-compose workers × kernel threads from the core count,
/// see [`auto_split`]); per-shard kernels additionally honour
/// `job.params.exec`.
pub struct Coordinator {
    pub workers: usize,
    pub metrics: Arc<Metrics>,
}

/// A shard work item: columns [start, end) of Ω.
struct Shard {
    start: usize,
    omega: Mat,
}

/// Compose (shard workers, kernel threads per shard) from the core
/// count: shard workers first — column chains never interact, so shard
/// parallelism is the cheap axis — then leftover cores as kernel
/// threads, with workers × threads ≤ cores always.
pub fn auto_split(cores: usize, shards: usize) -> (usize, usize) {
    let cores = cores.max(1);
    let workers = shards.clamp(1, cores);
    (workers, (cores / workers).max(1))
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Coordinator { workers, metrics: Arc::new(Metrics::default()) }
    }

    /// Auto-composing coordinator (`workers == 0`): picks shard workers
    /// × kernel threads per job from the machine's core count.
    pub fn auto() -> Self {
        Coordinator::new(0)
    }

    /// Run an embedding job over `op`, sharding Ω's columns across the
    /// worker pool. Deterministic given `job.seed`. Fails softly — a
    /// shard that exhausts its retry budget, a blown-up recurrence, or a
    /// missed deadline returns a [`JobError`] and leaves the coordinator
    /// (and the process-wide pool) fully reusable for the next job.
    pub fn run<O: Operator + Sync + ?Sized>(
        &self,
        op: &O,
        job: &EmbedJob,
    ) -> Result<JobResult, JobError> {
        let n = op.dim();
        let mut rng = Rng::new(job.seed);
        let d = if job.params.d > 0 {
            job.params.d
        } else {
            (6.0 * (n.max(2) as f64).ln()).ceil() as usize
        };
        let omega = rademacher_omega(&mut rng, n, d);
        self.run_with_omega(op, job, omega)
    }

    /// Same, with caller-supplied Ω (tests / resumable jobs).
    pub fn run_with_omega<O: Operator + Sync + ?Sized>(
        &self,
        op: &O,
        job: &EmbedJob,
        omega: Mat,
    ) -> Result<JobResult, JobError> {
        let n = op.dim();
        assert_eq!(omega.rows, n);
        let d = omega.cols;
        let mut rng = Rng::new(job.seed ^ 0x9E37_79B9_7F4A_7C15);

        // Resolve the two parallelism axes: explicit knobs always pass
        // through; `workers == 0` auto-composes the worker count, and
        // `job.auto_threads` opts the kernel thread count into the same
        // core-budget split (`workers × threads ≤ cores`). The budget
        // counts *physical* cores (SMT sibling groups share execution
        // ports, so hyperthreads are not full cores for these
        // bandwidth-bound kernels); single-node fallback detection
        // degrades to `available_parallelism()`.
        let cores = crate::par::topo::detect().physical_cores();
        let width = if job.shard_width == 0 {
            let workers_hint = if self.workers == 0 { cores } else { self.workers };
            par::adaptive_shard_width(n, d, workers_hint)
        } else {
            job.shard_width
        }
        .clamp(1, d);
        let nshards = d.div_ceil(width);
        let (workers, auto_t) = if self.workers == 0 {
            auto_split(cores, nshards)
        } else {
            (self.workers, (cores / self.workers).max(1))
        };
        let exec = if job.auto_threads {
            ExecPolicy::with_threads(auto_t)
        } else {
            job.params.exec
        };
        let exec = &exec;

        self.metrics.set_threads(exec.threads);
        let kappa = match &job.params.norm_est {
            Some(pe) => spectral_norm(op, pe, &mut rng, exec).max(1e-300),
            None => 1.0,
        };
        let plan = plan_scaled(
            &job.f,
            kappa,
            job.params.order,
            job.params.cascade,
            job.params.basis,
        );

        // Build shards (column slices of Ω).
        let queue: BoundedQueue<Shard> = BoundedQueue::new(2 * workers);
        self.metrics.shards_total.store(nshards, Ordering::Relaxed);
        self.metrics.shards_done.store(0, Ordering::Relaxed);

        let scaled = ScaledOp::new(op, 1.0 / kappa, 0.0);
        let total_matvecs = AtomicUsize::new(0);
        let job_retries = AtomicUsize::new(0);
        let results: Vec<Mutex<Option<Mat>>> = (0..nshards).map(|_| Mutex::new(None)).collect();

        // One token for the whole job: trips on the deadline (polled
        // down to row-block granularity inside the kernels) or on the
        // first unrecoverable shard failure, stopping the producer and
        // turning remaining workers into drain-and-discard loops so the
        // bounded queue can never deadlock a failing job.
        let started = Instant::now();
        let cancel = match job.deadline_ms {
            Some(ms) => CancelToken::with_deadline(Duration::from_millis(ms)),
            None => CancelToken::new(),
        };
        let first_error: Mutex<Option<JobError>> = Mutex::new(None);

        std::thread::scope(|scope| {
            // Workers, each owning a recycling workspace: after the first
            // shard the recursion allocates nothing.
            for _ in 0..workers {
                let queue = &queue;
                let plan = &plan;
                let scaled = &scaled;
                let results = &results;
                let total = &total_matvecs;
                let retries = &job_retries;
                let first_error = &first_error;
                let cancel = cancel.clone();
                let metrics = Arc::clone(&self.metrics);
                scope.spawn(move || {
                    let mut ws = Workspace::new();
                    ws.cancel = Some(cancel.clone());
                    loop {
                        // Queue wait vs. run time, attributed separately
                        // (the wait that ends in shutdown is discarded).
                        let mut wait = crate::obs::span(&crate::obs::SHARD_WAIT);
                        let Some(shard) = queue.pop() else {
                            wait.cancel();
                            break;
                        };
                        drop(wait);
                        if cancel.is_cancelled() {
                            // Keep draining so the producer never blocks
                            // on a full queue mid-abort; shards are
                            // discarded, not run.
                            continue;
                        }
                        let _run = crate::obs::span(&crate::obs::SHARD_RUN);
                        let idx = shard.start / width;
                        match run_shard(
                            scaled,
                            plan,
                            &shard,
                            idx,
                            exec,
                            &mut ws,
                            job.max_retries,
                            job.retry_backoff_ms,
                            &cancel,
                            &metrics,
                        ) {
                            ShardOutcome::Done { e, matvecs, attempts } => {
                                total.fetch_add(matvecs, Ordering::Relaxed);
                                retries.fetch_add(attempts - 1, Ordering::Relaxed);
                                metrics.add_matvecs(matvecs);
                                *results[idx].lock().unwrap() = Some(e);
                                metrics.shard_done();
                            }
                            ShardOutcome::Cancelled => {}
                            ShardOutcome::Failed(err) => {
                                crate::obs::failstats::SHARD_FAILURES
                                    .fetch_add(1, Ordering::Relaxed);
                                let mut slot =
                                    first_error.lock().unwrap_or_else(|p| p.into_inner());
                                if slot.is_none() {
                                    *slot = Some(err);
                                }
                                drop(slot);
                                cancel.cancel();
                            }
                        }
                    }
                });
            }
            // Producer: slice Ω into shards (backpressure via the queue).
            let mut start = 0;
            while start < d {
                if cancel.is_cancelled() {
                    break;
                }
                let end = (start + width).min(d);
                let mut cols = Mat::zeros(n, end - start);
                for i in 0..n {
                    cols.row_mut(i)
                        .copy_from_slice(&omega.row(i)[start..end]);
                }
                if queue.push(Shard { start, omega: cols }).is_err() {
                    break; // queue closed under us: abort in progress
                }
                start = end;
            }
            queue.close();
        });

        if let Some(err) = first_error.into_inner().unwrap_or_else(|p| p.into_inner()) {
            return Err(err);
        }
        if cancel.is_cancelled() {
            crate::obs::failstats::DEADLINE_ABORTS.fetch_add(1, Ordering::Relaxed);
            self.metrics.deadline_aborts.fetch_add(1, Ordering::Relaxed);
            return Err(JobError::DeadlineExceeded {
                done: self.metrics.shards_done.load(Ordering::Relaxed),
                total: nshards,
                elapsed_ms: started.elapsed().as_millis() as u64,
            });
        }

        // Reassemble.
        let mut e = Mat::zeros(n, d);
        for (s, slot) in results.iter().enumerate() {
            let shard = slot
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .take()
                .ok_or_else(|| JobError::Internal(format!("missing result for shard {s}")))?;
            let start = s * width;
            for i in 0..n {
                e.row_mut(i)[start..start + shard.cols].copy_from_slice(shard.row(i));
            }
        }
        Ok(JobResult {
            e,
            plan,
            norm_estimate: kappa,
            matvecs: total_matvecs.into_inner(),
            shards: nshards,
            workers,
            threads: exec.threads,
            retries: job_retries.into_inner(),
        })
    }
}

/// Terminal state of one shard after retries.
enum ShardOutcome {
    Done { e: Mat, matvecs: usize, attempts: usize },
    Cancelled,
    Failed(JobError),
}

/// Why a single attempt failed (retryable until the budget runs out).
enum AttemptError {
    Panicked(String),
    Blowup { stage: usize },
}

/// Run one shard with bounded retry. Each attempt recomputes the full
/// cascade from the shard's (immutable) Ω slice, so a retried shard
/// produces exactly the bits a first-try shard would — determinism is
/// preserved by construction, and the matvec count added on success is
/// the clean single-pass count (failed attempts are not billed).
#[allow(clippy::too_many_arguments)]
fn run_shard(
    op: &(impl Operator + ?Sized),
    plan: &CascadePlan,
    shard: &Shard,
    idx: usize,
    exec: &ExecPolicy,
    ws: &mut Workspace,
    max_retries: usize,
    retry_backoff_ms: u64,
    cancel: &CancelToken,
    metrics: &Metrics,
) -> ShardOutcome {
    let mut attempt = 0usize;
    loop {
        if cancel.is_cancelled() {
            return ShardOutcome::Cancelled;
        }
        let retry_span =
            if attempt > 0 { Some(crate::obs::span(&crate::obs::SHARD_RETRY)) } else { None };
        let result = run_attempt(op, plan, shard, exec, ws, cancel);
        drop(retry_span);
        match result {
            Ok(Some((e, matvecs))) => {
                return ShardOutcome::Done { e, matvecs, attempts: attempt + 1 }
            }
            Ok(None) => return ShardOutcome::Cancelled,
            Err(err) if attempt >= max_retries => {
                return ShardOutcome::Failed(match err {
                    AttemptError::Panicked(reason) => {
                        JobError::ShardFailed { shard: idx, attempts: attempt + 1, reason }
                    }
                    AttemptError::Blowup { stage } => {
                        JobError::NumericalBlowup { shard: idx, stage, stages: plan.b }
                    }
                });
            }
            Err(_) => {
                attempt += 1;
                metrics.shard_retry();
                crate::obs::failstats::SHARD_RETRIES.fetch_add(1, Ordering::Relaxed);
                // Jittered backoff before re-executing: spreads retry
                // storms out in time (transient resource pressure) and
                // de-synchronizes shards that failed together. The
                // delay is a pure function of (shard, attempt), so
                // fault-injected runs remain exactly reproducible.
                let delay = backoff_delay_ms(retry_backoff_ms, idx, attempt);
                if delay > 0 && !cancel.is_cancelled() {
                    std::thread::sleep(Duration::from_millis(delay));
                }
            }
        }
    }
}

/// Backoff delay before retry `attempt` (1-based) of shard `shard_idx`:
/// exponential ceiling `base · 2^min(attempt−1, 6)` (capped so a deep
/// retry chain can't sleep unboundedly), jittered into `[c/2, c]` by a
/// splitmix64 hash of `(shard_idx, attempt)`. Pure and deterministic —
/// identical inputs always produce the identical delay — so
/// fault-injected runs (`--fault-spec` seeds) reproduce exactly.
/// Returns 0 when `base_ms == 0` (backoff disabled).
fn backoff_delay_ms(base_ms: u64, shard_idx: usize, attempt: usize) -> u64 {
    if base_ms == 0 {
        return 0;
    }
    let exp = attempt.saturating_sub(1).min(6) as u32;
    let ceiling = base_ms.saturating_mul(1u64 << exp);
    let mut state = (shard_idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ attempt as u64;
    let h = crate::util::rng::splitmix64(&mut state);
    // Top 53 bits → uniform in [0, 1), mapped to [c/2, c].
    let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
    ((ceiling as f64 * (0.5 + 0.5 * unit)).round() as u64).max(1)
}

/// One isolated execution attempt: panics inside the recurrence (or
/// injected by the chaos harness) are caught and reported as data, and
/// every stage output is checked for finiteness so a blown-up recurrence
/// names its stage instead of poisoning the assembled embedding.
/// `Ok(None)` = cancelled mid-attempt (partial state already retired).
fn run_attempt(
    op: &(impl Operator + ?Sized),
    plan: &CascadePlan,
    shard: &Shard,
    exec: &ExecPolicy,
    ws: &mut Workspace,
    cancel: &CancelToken,
) -> Result<Option<(Mat, usize)>, AttemptError> {
    let caught = catch_unwind(AssertUnwindSafe(|| {
        // Chaos failpoint: no-op (one relaxed load) unless armed.
        let injected = crate::fault::inject("shard_run");
        let mut mv = 0usize;
        // Work on a copy so the shard's Ω slice survives for retries.
        let mut e = ws.take_mat(shard.omega.rows, shard.omega.cols);
        e.data.copy_from_slice(&shard.omega.data);
        for stage in 0..plan.b {
            let next = apply_series_ws(op, &plan.stage, &e, &mut mv, exec, ws);
            ws.give_mat(e);
            e = next;
            if cancel.is_cancelled() {
                ws.give_mat(e);
                return Ok(None);
            }
            if stage == 0 {
                if let (Some(FaultKind::Poison), Some(v)) = (injected, e.data.first_mut()) {
                    *v = f64::NAN; // injected data corruption
                }
            }
            if !block_is_finite(&e) {
                ws.give_mat(e);
                return Err(AttemptError::Blowup { stage });
            }
        }
        Ok(Some((e, mv)))
    }));
    match caught {
        Ok(r) => r,
        Err(payload) => Err(AttemptError::Panicked(panic_message(payload.as_ref()))),
    }
}

/// Single-pass finiteness probe: the sum of squares is finite iff every
/// element is finite and no square overflows — embedding-stage outputs
/// are O(1) per element, so overflow only happens when the recurrence
/// has genuinely diverged.
fn block_is_finite(m: &Mat) -> bool {
    m.data.iter().map(|v| v * v).sum::<f64>().is_finite()
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::FastEmbed;
    use crate::poly::Basis;
    use crate::sparse::{gen, graph};
    use crate::testing::prop::{check, forall};

    fn job(d: usize, order: usize, cascade: usize, width: usize) -> EmbedJob {
        EmbedJob {
            params: Params { d, order, cascade, ..Params::default() },
            f: SpectralFn::Step { c: 0.5 },
            shard_width: width,
            seed: 99,
            auto_threads: false,
            max_retries: DEFAULT_MAX_RETRIES,
            deadline_ms: None,
            retry_backoff_ms: 0,
        }
    }

    #[test]
    fn backoff_delay_is_deterministic_and_bounded() {
        assert_eq!(backoff_delay_ms(0, 3, 1), 0, "base 0 disables backoff");
        for idx in 0..5usize {
            for attempt in 1..=10usize {
                let a = backoff_delay_ms(20, idx, attempt);
                let b = backoff_delay_ms(20, idx, attempt);
                assert_eq!(a, b, "must be a pure function of (shard, attempt)");
                let ceiling = 20u64 << attempt.saturating_sub(1).min(6) as u32;
                assert!(
                    a >= ceiling / 2 && a <= ceiling,
                    "delay {a} outside [{}, {ceiling}] at attempt {attempt}",
                    ceiling / 2
                );
            }
        }
        // The jitter must actually spread simultaneous failures apart.
        let delays: Vec<u64> = (0..16).map(|i| backoff_delay_ms(100, i, 3)).collect();
        let first = delays[0];
        assert!(delays.iter().any(|&d| d != first), "jitter never varies across shards");
    }

    #[test]
    fn backoff_does_not_change_result_bits() {
        let mut rng = Rng::new(218);
        let g = gen::erdos_renyi(&mut rng, 60, 180);
        let na = graph::normalized_adjacency(&g.adj);
        let base = Coordinator::new(2).run(&na, &job(12, 16, 1, 4)).unwrap();
        let mut jb = job(12, 16, 1, 4);
        jb.retry_backoff_ms = 5;
        let with_backoff = Coordinator::new(2).run(&na, &jb).unwrap();
        assert_eq!(base.e.data, with_backoff.e.data);
        assert_eq!(with_backoff.retries, 0, "backoff alone must not cause retries");
    }

    #[test]
    fn sharded_equals_unsharded_bitexact() {
        forall(
            211,
            6,
            |r| {
                let n = 30 + r.below(40);
                let g = gen::erdos_renyi(r, n, n * 3);
                let width = 1 + r.below(5);
                let workers = 1 + r.below(4);
                (graph::normalized_adjacency(&g.adj), width, workers)
            },
            |(na, width, workers)| {
                let j = job(16, 24, 2, *width);
                let mut rng = Rng::new(j.seed);
                let omega = rademacher_omega(&mut rng, na.rows, 16);

                let coord = Coordinator::new(*workers);
                let sharded = coord.run_with_omega(na, &j, omega.clone()).unwrap();

                let fe = FastEmbed::new(j.params.clone());
                let mut rng2 = Rng::new(0);
                let direct = fe.embed_with_omega(na, &j.f, omega, &mut rng2);

                check(
                    sharded.e.max_abs_diff(&direct.e) == 0.0,
                    format!("shard mismatch {}", sharded.e.max_abs_diff(&direct.e)),
                )?;
                check(sharded.matvecs == direct.matvecs, "matvec accounting")?;
                Ok(())
            },
        );
    }

    #[test]
    fn shard_count_and_metrics() {
        let mut rng = Rng::new(212);
        let g = gen::erdos_renyi(&mut rng, 60, 180);
        let na = graph::normalized_adjacency(&g.adj);
        let coord = Coordinator::new(3);
        let j = job(20, 12, 1, 6);
        let res = coord.run(&na, &j).unwrap();
        assert_eq!(res.shards, 4); // ceil(20/6)
        assert_eq!(res.retries, 0, "healthy run must not retry");
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.shards_done, 4);
        assert_eq!(snap.shards_total, 4);
        assert_eq!(snap.matvecs, res.matvecs);
        assert_eq!(res.e.cols, 20);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let mut rng = Rng::new(213);
        let g = gen::sbm_by_degree(&mut rng, 80, 4, 6.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let j = job(12, 20, 2, 3);
        let a = Coordinator::new(1).run(&na, &j).unwrap();
        let b = Coordinator::new(4).run(&na, &j).unwrap();
        assert_eq!(a.e.data, b.e.data);
    }

    #[test]
    fn deterministic_across_kernel_thread_counts() {
        // Both parallelism axes at once: shard workers × ExecPolicy
        // threads inside each shard's block products.
        let mut rng = Rng::new(215);
        let g = gen::sbm_by_degree(&mut rng, 120, 4, 6.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let base = Coordinator::new(1).run(&na, &job(10, 16, 2, 4)).unwrap();
        for (workers, threads) in [(1usize, 2usize), (2, 2), (3, 4)] {
            let mut j = job(10, 16, 2, 4);
            j.params.exec = crate::par::ExecPolicy::with_threads(threads);
            let coord = Coordinator::new(workers);
            let res = coord.run(&na, &j).unwrap();
            assert_eq!(base.e.data, res.e.data, "workers={workers} threads={threads}");
            assert_eq!(coord.metrics.snapshot().threads, threads);
        }
    }

    #[test]
    fn auto_split_composes_within_core_budget() {
        for (cores, shards, want) in [
            (8usize, 3usize, (3usize, 2usize)), // 3 workers × 2 threads = 6 ≤ 8
            (8, 1, (1, 8)),                     // single shard: all cores go to kernels
            (8, 20, (8, 1)),                    // many shards: all cores go to workers
            (4, 4, (4, 1)),
            (1, 5, (1, 1)),
            (0, 0, (1, 1)), // degenerate inputs clamp sanely
        ] {
            assert_eq!(auto_split(cores, shards), want, "cores={cores} shards={shards}");
        }
        for cores in 1..=16 {
            for shards in 1..=32 {
                let (w, t) = auto_split(cores, shards);
                assert!(w * t <= cores.max(1), "oversubscribed: {w}x{t} on {cores}");
                assert!(w >= 1 && t >= 1);
            }
        }
    }

    #[test]
    fn auto_coordinator_matches_manual_bitexact() {
        let mut rng = Rng::new(216);
        let g = gen::sbm_by_degree(&mut rng, 100, 4, 6.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let j = job(12, 16, 2, 4);
        let manual = Coordinator::new(2).run(&na, &j).unwrap();
        // Fully automatic: workers and kernel threads both composed.
        let mut ja = job(12, 16, 2, 4);
        ja.auto_threads = true;
        let auto = Coordinator::auto().run(&na, &ja).unwrap();
        assert_eq!(manual.e.data, auto.e.data, "auto-composition must not change bits");
        assert_eq!(auto.shards, 3);
        assert!(auto.workers >= 1 && auto.threads >= 1);
        let cores = std::thread::available_parallelism().map_or(1, |c| c.get());
        assert!(auto.workers * auto.threads <= cores.max(1));
        // An explicit kernel policy — including deliberately serial — is
        // always respected by the auto coordinator.
        let mut jt = job(12, 16, 2, 4);
        jt.params.exec = crate::par::ExecPolicy::with_threads(2);
        let auto_t = Coordinator::auto().run(&na, &jt).unwrap();
        assert_eq!(auto_t.threads, 2);
        assert_eq!(manual.e.data, auto_t.e.data);
        let serial = Coordinator::auto().run(&na, &job(12, 16, 2, 4)).unwrap();
        assert_eq!(serial.threads, 1, "explicit serial kernels must not be overridden");
        assert_eq!(manual.e.data, serial.e.data);
    }

    #[test]
    fn adaptive_width_matches_explicit_bitexact() {
        let mut rng = Rng::new(217);
        let g = gen::erdos_renyi(&mut rng, 70, 210);
        let na = graph::normalized_adjacency(&g.adj);
        let explicit = Coordinator::new(2).run(&na, &job(16, 16, 2, 4)).unwrap();
        let adaptive = Coordinator::new(2).run(&na, &job(16, 16, 2, 0)).unwrap();
        assert_eq!(
            explicit.e.data, adaptive.e.data,
            "adaptive width must not change bits"
        );
        // n=70, d=16, 2 workers: the fair split (16/2 = 8, already a
        // lane multiple) binds → 2 shards of width 8.
        assert_eq!(adaptive.shards, 2);
    }

    #[test]
    fn auto_d_used_when_zero() {
        let mut rng = Rng::new(214);
        let g = gen::erdos_renyi(&mut rng, 50, 100);
        let na = graph::normalized_adjacency(&g.adj);
        let j = job(0, 8, 1, 4);
        let res = Coordinator::new(2).run(&na, &j).unwrap();
        let want = (6.0 * (50f64).ln()).ceil() as usize;
        assert_eq!(res.e.cols, want);
    }
}
