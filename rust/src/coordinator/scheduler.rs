//! The column-shard scheduler: the paper's "run the 2L matrix-vector
//! chains in parallel across the d starting vectors", implemented as a
//! worker pool over column shards of Ω.
//!
//! Sharding is *exact*: each shard runs the identical recursion on a
//! column subset of Ω, and column chains never interact, so the
//! reassembled embedding is bit-identical to the unsharded driver
//! (property-tested below). Shard width also bounds worker memory:
//! 3 ping-pong blocks of n × shard_width doubles.
//!
//! Two parallelism axes compose here: `workers` shard-level threads (this
//! pool) × `job.params.exec.threads` row-parallel threads inside each
//! shard's block products (`crate::par`). Both are deterministic, so any
//! (workers, threads) combination produces the same embedding; keep
//! workers × threads ≤ cores to avoid oversubscription. Wide graphs with
//! few columns want `exec` threads; many-column jobs want workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use super::metrics::Metrics;
use super::queue::BoundedQueue;
use crate::embed::fastembed::{apply_series, plan_scaled};
use crate::embed::norm::spectral_norm;
use crate::embed::omega::rademacher_omega;
use crate::embed::op::{Operator, ScaledOp};
use crate::embed::Params;
use crate::funcs::SpectralFn;
use crate::linalg::Mat;
use crate::poly::cascade::CascadePlan;
use crate::util::rng::Rng;

/// An embedding job specification.
#[derive(Clone, Debug)]
pub struct EmbedJob {
    pub params: Params,
    pub f: SpectralFn,
    /// Column-shard width (starting vectors per work item).
    pub shard_width: usize,
    pub seed: u64,
}

impl EmbedJob {
    pub fn new(params: Params, f: SpectralFn, seed: u64) -> Self {
        EmbedJob { params, f, shard_width: 8, seed }
    }
}

/// Result: the reassembled embedding + execution telemetry.
pub struct JobResult {
    pub e: Mat,
    pub plan: CascadePlan,
    pub norm_estimate: f64,
    pub matvecs: usize,
    pub shards: usize,
}

/// Worker-pool coordinator. `workers` is the shard-level pool size;
/// per-shard kernels additionally honour `job.params.exec`.
pub struct Coordinator {
    pub workers: usize,
    pub metrics: Arc<Metrics>,
}

/// A shard work item: columns [start, end) of Ω.
struct Shard {
    start: usize,
    omega: Mat,
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Coordinator { workers: workers.max(1), metrics: Arc::new(Metrics::default()) }
    }

    /// Run an embedding job over `op`, sharding Ω's columns across the
    /// worker pool. Deterministic given `job.seed`.
    pub fn run<O: Operator + Sync + ?Sized>(&self, op: &O, job: &EmbedJob) -> JobResult {
        let n = op.dim();
        let mut rng = Rng::new(job.seed);
        let d = if job.params.d > 0 {
            job.params.d
        } else {
            (6.0 * (n.max(2) as f64).ln()).ceil() as usize
        };
        let omega = rademacher_omega(&mut rng, n, d);
        self.run_with_omega(op, job, omega)
    }

    /// Same, with caller-supplied Ω (tests / resumable jobs).
    pub fn run_with_omega<O: Operator + Sync + ?Sized>(
        &self,
        op: &O,
        job: &EmbedJob,
        omega: Mat,
    ) -> JobResult {
        let n = op.dim();
        assert_eq!(omega.rows, n);
        let d = omega.cols;
        let mut rng = Rng::new(job.seed ^ 0x9E37_79B9_7F4A_7C15);
        self.metrics.set_threads(job.params.exec.threads);
        let kappa = match &job.params.norm_est {
            Some(pe) => spectral_norm(op, pe, &mut rng, &job.params.exec).max(1e-300),
            None => 1.0,
        };
        let plan = plan_scaled(
            &job.f,
            kappa,
            job.params.order,
            job.params.cascade,
            job.params.basis,
        );

        // Build shards (column slices of Ω).
        let width = job.shard_width.clamp(1, d);
        let queue: BoundedQueue<Shard> = BoundedQueue::new(2 * self.workers.max(1));
        let nshards = d.div_ceil(width);
        self.metrics.shards_total.store(nshards, Ordering::Relaxed);
        self.metrics.shards_done.store(0, Ordering::Relaxed);

        let scaled = ScaledOp::new(op, 1.0 / kappa, 0.0);
        let total_matvecs = AtomicUsize::new(0);
        let results: Vec<std::sync::Mutex<Option<Mat>>> =
            (0..nshards).map(|_| std::sync::Mutex::new(None)).collect();

        std::thread::scope(|scope| {
            // Workers.
            for _ in 0..self.workers {
                let queue = &queue;
                let plan = &plan;
                let scaled = &scaled;
                let results = &results;
                let total = &total_matvecs;
                let metrics = Arc::clone(&self.metrics);
                let exec = &job.params.exec;
                scope.spawn(move || {
                    while let Some(shard) = queue.pop() {
                        let mut mv = 0usize;
                        let mut e = shard.omega;
                        for _ in 0..plan.b {
                            e = apply_series(scaled, &plan.stage, &e, &mut mv, exec);
                        }
                        total.fetch_add(mv, Ordering::Relaxed);
                        metrics.add_matvecs(mv);
                        let idx = shard.start / width;
                        *results[idx].lock().unwrap() = Some(e);
                        metrics.shard_done();
                    }
                });
            }
            // Producer: slice Ω into shards (backpressure via the queue).
            let mut start = 0;
            while start < d {
                let end = (start + width).min(d);
                let mut cols = Mat::zeros(n, end - start);
                for i in 0..n {
                    cols.row_mut(i)
                        .copy_from_slice(&omega.row(i)[start..end]);
                }
                queue
                    .push(Shard { start, omega: cols })
                    .unwrap_or_else(|_| panic!("queue closed early"));
                start = end;
            }
            queue.close();
        });

        // Reassemble.
        let mut e = Mat::zeros(n, d);
        for (s, slot) in results.iter().enumerate() {
            let shard = slot.lock().unwrap().take().expect("missing shard result");
            let start = s * width;
            for i in 0..n {
                e.row_mut(i)[start..start + shard.cols].copy_from_slice(shard.row(i));
            }
        }
        JobResult {
            e,
            plan,
            norm_estimate: kappa,
            matvecs: total_matvecs.into_inner(),
            shards: nshards,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::FastEmbed;
    use crate::poly::Basis;
    use crate::sparse::{gen, graph};
    use crate::testing::prop::{check, forall};

    fn job(d: usize, order: usize, cascade: usize, width: usize) -> EmbedJob {
        EmbedJob {
            params: Params { d, order, cascade, ..Params::default() },
            f: SpectralFn::Step { c: 0.5 },
            shard_width: width,
            seed: 99,
        }
    }

    #[test]
    fn sharded_equals_unsharded_bitexact() {
        forall(
            211,
            6,
            |r| {
                let n = 30 + r.below(40);
                let g = gen::erdos_renyi(r, n, n * 3);
                let width = 1 + r.below(5);
                let workers = 1 + r.below(4);
                (graph::normalized_adjacency(&g.adj), width, workers)
            },
            |(na, width, workers)| {
                let j = job(16, 24, 2, *width);
                let mut rng = Rng::new(j.seed);
                let omega = rademacher_omega(&mut rng, na.rows, 16);

                let coord = Coordinator::new(*workers);
                let sharded = coord.run_with_omega(na, &j, omega.clone());

                let fe = FastEmbed::new(j.params.clone());
                let mut rng2 = Rng::new(0);
                let direct = fe.embed_with_omega(na, &j.f, omega, &mut rng2);

                check(
                    sharded.e.max_abs_diff(&direct.e) == 0.0,
                    format!("shard mismatch {}", sharded.e.max_abs_diff(&direct.e)),
                )?;
                check(sharded.matvecs == direct.matvecs, "matvec accounting")?;
                Ok(())
            },
        );
    }

    #[test]
    fn shard_count_and_metrics() {
        let mut rng = Rng::new(212);
        let g = gen::erdos_renyi(&mut rng, 60, 180);
        let na = graph::normalized_adjacency(&g.adj);
        let coord = Coordinator::new(3);
        let j = job(20, 12, 1, 6);
        let res = coord.run(&na, &j);
        assert_eq!(res.shards, 4); // ceil(20/6)
        let snap = coord.metrics.snapshot();
        assert_eq!(snap.shards_done, 4);
        assert_eq!(snap.shards_total, 4);
        assert_eq!(snap.matvecs, res.matvecs);
        assert_eq!(res.e.cols, 20);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let mut rng = Rng::new(213);
        let g = gen::sbm_by_degree(&mut rng, 80, 4, 6.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let j = job(12, 20, 2, 3);
        let a = Coordinator::new(1).run(&na, &j);
        let b = Coordinator::new(4).run(&na, &j);
        assert_eq!(a.e.data, b.e.data);
    }

    #[test]
    fn deterministic_across_kernel_thread_counts() {
        // Both parallelism axes at once: shard workers × ExecPolicy
        // threads inside each shard's block products.
        let mut rng = Rng::new(215);
        let g = gen::sbm_by_degree(&mut rng, 120, 4, 6.0, 1.0);
        let na = graph::normalized_adjacency(&g.adj);
        let base = Coordinator::new(1).run(&na, &job(10, 16, 2, 4));
        for (workers, threads) in [(1usize, 2usize), (2, 2), (3, 4)] {
            let mut j = job(10, 16, 2, 4);
            j.params.exec = crate::par::ExecPolicy::with_threads(threads);
            let coord = Coordinator::new(workers);
            let res = coord.run(&na, &j);
            assert_eq!(base.e.data, res.e.data, "workers={workers} threads={threads}");
            assert_eq!(coord.metrics.snapshot().threads, threads);
        }
    }

    #[test]
    fn auto_d_used_when_zero() {
        let mut rng = Rng::new(214);
        let g = gen::erdos_renyi(&mut rng, 50, 100);
        let na = graph::normalized_adjacency(&g.adj);
        let j = job(0, 8, 1, 4);
        let res = Coordinator::new(2).run(&na, &j);
        let want = (6.0 * (50f64).ln()).ceil() as usize;
        assert_eq!(res.e.cols, want);
    }
}
