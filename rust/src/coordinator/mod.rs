//! Layer-3 coordination: turning Algorithm 1 into a deployable system.
//!
//! The paper's key systems observation (§1, §3.2) is that the embedding
//! factorizes into `d` *independent* column chains — "a sequence of 2L
//! matrix-vector products … run in parallel across d randomly chosen
//! starting vectors". This module owns that execution strategy:
//!
//! * [`queue`]   — bounded blocking queue (the backpressure primitive;
//!   no tokio offline, so std sync primitives).
//! * [`scheduler`] — the column-shard scheduler: splits Ω into column
//!   shards, runs the recursion per shard on a worker pool, reassembles.
//!   Shard execution is bit-exact with the unsharded driver (property-
//!   tested), so parallelism is purely an execution concern. Inside each
//!   shard the block products additionally honour the job's
//!   `ExecPolicy` ([`crate::par`]) for row-range threading.
//! * [`service`] — the similarity-query service: owns a finished
//!   embedding and answers normalized-correlation / top-k queries, the
//!   "downstream inference" interface (§1) batched behind a queue.
//!   Top-k optionally routes through a `crate::index` ANN index
//!   (sublinear candidates + exact re-ranking).
//! * [`metrics`] — atomic counters/gauges exported by the CLI.
//! * [`error`]   — the typed [`JobError`] every fallible path returns:
//!   shard panics past the retry budget, numerical blow-ups, missed
//!   deadlines, invalid inputs. The process survives all of them.

pub mod error;
pub mod metrics;
pub mod queue;
pub mod scheduler;
pub mod service;

pub use error::JobError;
pub use scheduler::{Coordinator, EmbedJob, JobResult};
pub use service::{measure_serving, QueryBatch, ServingSample, SimilarityService};
