//! Bounded blocking MPMC queue — the backpressure primitive used between
//! the request side and worker pools (std-only; no tokio offline).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// A bounded blocking queue. `push` blocks while full (backpressure),
/// `pop` blocks while empty; `close` wakes everyone and drains.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        BoundedQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Blocking push. Returns `Err(item)` if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        while g.items.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(item);
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. `None` when the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        let item = g.items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Close the queue: pending pops drain then return None; pushes fail.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(7).unwrap();
        q.close();
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        assert!(q.push(8).is_err());
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            // This blocks until the main thread pops.
            q2.push(2).unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 1, "push must be blocked while full");
        assert_eq!(q.pop(), Some(1));
        h.join().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn producers_and_consumers() {
        let q = Arc::new(BoundedQueue::new(8));
        let total = 200;
        let mut handles = Vec::new();
        for t in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..total / 4 {
                    q.push(t * 1000 + i).unwrap();
                }
            }));
        }
        let consumer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(v) = q.pop() {
                    seen.push(v);
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let seen = consumer.join().unwrap();
        assert_eq!(seen.len(), total);
    }
}
