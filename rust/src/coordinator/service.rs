//! Similarity-query service over a finished embedding.
//!
//! The embedding exists to answer ℓ₂-derived similarity queries (§1);
//! this is the serving half of the system: normalized-correlation and
//! top-k neighbour queries over the rows of Ẽ, batched behind a bounded
//! queue and executed by a worker pool. Row norms are precomputed once,
//! so a pairwise query is O(d) and a top-k scan O(n·d).

use std::sync::Arc;

use super::metrics::Metrics;
use super::queue::BoundedQueue;
use crate::linalg::Mat;

/// A single query.
#[derive(Clone, Debug)]
pub enum Query {
    /// Normalized correlation between two vertices.
    Corr { i: usize, j: usize },
    /// Top-k most correlated vertices to `i` (excluding `i`).
    TopK { i: usize, k: usize },
}

/// A query answer.
#[derive(Clone, Debug, PartialEq)]
pub enum Answer {
    Corr(f64),
    TopK(Vec<(usize, f64)>),
}

/// The service: an embedding with precomputed row norms.
pub struct SimilarityService {
    e: Mat,
    norms: Vec<f64>,
    pub metrics: Arc<Metrics>,
}

impl SimilarityService {
    pub fn new(e: Mat) -> Self {
        let norms = (0..e.rows).map(|i| e.row_norm(i)).collect();
        SimilarityService { e, norms, metrics: Arc::new(Metrics::default()) }
    }

    pub fn len(&self) -> usize {
        self.e.rows
    }

    pub fn is_empty(&self) -> bool {
        self.e.rows == 0
    }

    pub fn dim(&self) -> usize {
        self.e.cols
    }

    /// Normalized correlation of rows i, j (0 for zero rows).
    pub fn corr(&self, i: usize, j: usize) -> f64 {
        let (ni, nj) = (self.norms[i], self.norms[j]);
        if ni < 1e-300 || nj < 1e-300 {
            return 0.0;
        }
        self.e.row_dot(i, j) / (ni * nj)
    }

    /// Top-k most correlated vertices to `i` (linear scan + bounded heap).
    pub fn top_k(&self, i: usize, k: usize) -> Vec<(usize, f64)> {
        use std::cmp::Ordering;
        let mut heap: Vec<(usize, f64)> = Vec::with_capacity(k + 1); // min at end
        for j in 0..self.e.rows {
            if j == i {
                continue;
            }
            let c = self.corr(i, j);
            if heap.len() < k {
                heap.push((j, c));
                heap.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal));
            } else if let Some(last) = heap.last() {
                if c > last.1 {
                    heap.pop();
                    let pos = heap
                        .binary_search_by(|p| {
                            c.partial_cmp(&p.1).unwrap_or(Ordering::Equal)
                        })
                        .unwrap_or_else(|e| e);
                    heap.insert(pos, (j, c));
                }
            }
        }
        heap
    }

    /// Answer one query, recording latency.
    pub fn answer(&self, q: &Query) -> Answer {
        let t = std::time::Instant::now();
        let ans = match *q {
            Query::Corr { i, j } => Answer::Corr(self.corr(i, j)),
            Query::TopK { i, k } => Answer::TopK(self.top_k(i, k)),
        };
        self.metrics.record_query(t.elapsed().as_nanos() as u64);
        ans
    }
}

/// A batch executor: pushes queries through a bounded queue to a worker
/// pool, preserving input order in the answer vector.
pub struct QueryBatch;

impl QueryBatch {
    /// Execute `queries` with `workers` threads over `service`.
    pub fn run(service: &SimilarityService, queries: &[Query], workers: usize) -> Vec<Answer> {
        let workers = workers.max(1);
        let queue: BoundedQueue<(usize, Query)> = BoundedQueue::new(4 * workers);
        let slots: Vec<std::sync::Mutex<Option<Answer>>> =
            queries.iter().map(|_| std::sync::Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let queue = &queue;
                let slots = &slots;
                scope.spawn(move || {
                    while let Some((idx, q)) = queue.pop() {
                        *slots[idx].lock().unwrap() = Some(service.answer(&q));
                    }
                });
            }
            for (idx, q) in queries.iter().enumerate() {
                queue.push((idx, q.clone())).ok();
            }
            queue.close();
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("missing answer"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn service(n: usize, d: usize, seed: u64) -> SimilarityService {
        let mut rng = Rng::new(seed);
        SimilarityService::new(Mat::randn(&mut rng, n, d))
    }

    #[test]
    fn corr_agrees_with_mat_row_corr() {
        let s = service(20, 6, 221);
        for i in 0..20 {
            for j in 0..20 {
                assert!((s.corr(i, j) - s.e.row_corr(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn top_k_matches_exhaustive_sort() {
        let s = service(50, 5, 222);
        for &i in &[0, 7, 49] {
            let got = s.top_k(i, 5);
            let mut all: Vec<(usize, f64)> =
                (0..50).filter(|&j| j != i).map(|j| (j, s.corr(i, j))).collect();
            all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let want: Vec<usize> = all[..5].iter().map(|p| p.0).collect();
            let got_idx: Vec<usize> = got.iter().map(|p| p.0).collect();
            assert_eq!(got_idx, want, "top-k mismatch at {i}");
            // Scores descending.
            for w in got.windows(2) {
                assert!(w[0].1 >= w[1].1);
            }
        }
    }

    #[test]
    fn top_k_k_larger_than_n() {
        let s = service(5, 3, 223);
        let got = s.top_k(0, 100);
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn batch_preserves_order_and_matches_serial() {
        let s = service(40, 4, 224);
        let queries: Vec<Query> = (0..30)
            .map(|t| {
                if t % 2 == 0 {
                    Query::Corr { i: t % 40, j: (t * 7) % 40 }
                } else {
                    Query::TopK { i: t % 40, k: 3 }
                }
            })
            .collect();
        let serial: Vec<Answer> = queries.iter().map(|q| s.answer(q)).collect();
        let batched = QueryBatch::run(&s, &queries, 4);
        assert_eq!(serial, batched);
        assert!(s.metrics.snapshot().queries >= 60);
    }

    #[test]
    fn zero_row_corr_is_zero() {
        let mut e = Mat::zeros(3, 4);
        e.row_mut(1).copy_from_slice(&[1.0, 0.0, 0.0, 0.0]);
        let s = SimilarityService::new(e);
        assert_eq!(s.corr(0, 1), 0.0);
    }
}
