//! Similarity-query service over a finished embedding.
//!
//! The embedding exists to answer ℓ₂-derived similarity queries (§1);
//! this is the serving half of the system: normalized-correlation and
//! top-k neighbour queries over the rows of Ẽ, batched across the
//! persistent `par` pool ([`QueryBatch`]). Row norms are precomputed
//! once, so a pairwise query is O(d) and an exact top-k scan O(n·d).
//!
//! Top-k can optionally be routed through an [`AnnIndex`]
//! (`crate::index`): sublinear candidate generation + exact re-ranking,
//! with per-query candidate counts recorded in [`Metrics`]. Without an
//! index the service keeps the exact scan. Both paths rank by
//! `(correlation desc, vertex id asc)` so their answers are comparable
//! element-wise (ties no longer depend on scan order).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::error::JobError;
use super::metrics::Metrics;
use crate::index::{rerank_top_k, AnnIndex};
use crate::linalg::Mat;
use crate::par::{self, CancelToken, ExecPolicy};

/// Load shedding needs a latency sample before p99 means anything:
/// below this many recorded queries the threshold is never consulted.
const SHED_MIN_QUERIES: usize = 32;

/// A single query.
#[derive(Clone, Debug)]
pub enum Query {
    /// Normalized correlation between two vertices.
    Corr { i: usize, j: usize },
    /// Top-k most correlated vertices to `i` (excluding `i`).
    TopK { i: usize, k: usize },
}

/// A query answer.
#[derive(Clone, Debug, PartialEq)]
pub enum Answer {
    Corr(f64),
    TopK(Vec<(usize, f64)>),
    /// The query was rejected by load shedding (top-k p99 latency over
    /// the configured threshold). The caller may retry later.
    Shed,
}

/// The service: an embedding with precomputed row norms and an optional
/// ANN index accelerating top-k queries.
pub struct SimilarityService {
    e: Mat,
    norms: Vec<f64>,
    index: Option<Box<dyn AnnIndex>>,
    /// Shed top-k queries when query-latency p99 (µs) exceeds this.
    shed_p99_us: Option<f64>,
    pub metrics: Arc<Metrics>,
}

impl SimilarityService {
    pub fn new(e: Mat) -> Self {
        let norms = crate::index::row_norms(&e);
        SimilarityService {
            e,
            norms,
            index: None,
            shed_p99_us: None,
            metrics: Arc::new(Metrics::default()),
        }
    }

    /// Enable (or disable with `None`) load shedding: once at least
    /// [`SHED_MIN_QUERIES`] latencies are recorded and their p99 exceeds
    /// `us` microseconds, `Query::TopK` — the expensive class — is
    /// answered with [`Answer::Shed`] instead of being executed. Shed
    /// queries are counted but not recorded into the latency histogram,
    /// so cheap pairwise traffic keeps flowing and keeps the estimate
    /// honest.
    pub fn set_shed_threshold(&mut self, us: Option<f64>) {
        self.shed_p99_us = us;
    }

    /// Route `Query::TopK` through `index` (replaces any previous index).
    pub fn attach_index(&mut self, index: Box<dyn AnnIndex>) {
        assert_eq!(
            index.len(),
            self.e.rows,
            "index covers {} rows, embedding has {}",
            index.len(),
            self.e.rows
        );
        self.index = Some(index);
    }

    /// Drop the index, reverting top-k to the exact scan.
    pub fn detach_index(&mut self) -> Option<Box<dyn AnnIndex>> {
        self.index.take()
    }

    /// Name of the attached index, if any.
    pub fn index_name(&self) -> Option<&'static str> {
        self.index.as_deref().map(|i| i.name())
    }

    /// The served embedding (index builders hash its rows).
    pub fn embedding(&self) -> &Mat {
        &self.e
    }

    /// Precomputed row norms, aligned with [`Self::embedding`].
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    pub fn len(&self) -> usize {
        self.e.rows
    }

    pub fn is_empty(&self) -> bool {
        self.e.rows == 0
    }

    pub fn dim(&self) -> usize {
        self.e.cols
    }

    /// Normalized correlation of rows i, j (0 for zero rows).
    pub fn corr(&self, i: usize, j: usize) -> f64 {
        crate::index::row_corr(&self.e, &self.norms, i, j)
    }

    /// Exact top-k most correlated vertices to `i` (linear scan), ranked
    /// by `(correlation desc, id asc)`. This is the ground truth every
    /// index is measured against.
    pub fn top_k(&self, i: usize, k: usize) -> Vec<(usize, f64)> {
        rerank_top_k(&self.e, &self.norms, i, k, 0..self.e.rows)
    }

    /// Top-k through the attached index (exact scan when none), with
    /// candidate accounting. A probe that panics, or comes back empty
    /// when hits were clearly available, falls back to the exact scan —
    /// the scan is always correct, just `O(n·d)` — and the fallback is
    /// counted in [`Metrics::fallback_exact`] / `obs::failstats`.
    fn top_k_routed(&self, i: usize, k: usize) -> Vec<(usize, f64)> {
        if let Some(idx) = &self.index {
            let probe = catch_unwind(AssertUnwindSafe(|| idx.top_k(&self.e, &self.norms, i, k)));
            match probe {
                Ok(r) if !(r.hits.is_empty() && k > 0 && self.e.rows > 1) => {
                    self.metrics.record_topk(r.candidates);
                    return r.hits;
                }
                _ => {
                    crate::obs::failstats::FALLBACK_EXACT.fetch_add(1, Ordering::Relaxed);
                    self.metrics.fallback_exact.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        self.metrics.record_topk(self.e.rows.saturating_sub(1));
        self.top_k(i, k)
    }

    /// Answer one query, recording latency into the metrics histogram
    /// (and a `query` stage span when `--stats`/`--trace` is on).
    ///
    /// When a shed threshold is set and top-k p99 latency has crossed
    /// it, `Query::TopK` is rejected with [`Answer::Shed`] before any
    /// work is done (pairwise queries always run).
    pub fn answer(&self, q: &Query) -> Answer {
        if let (Some(th), Query::TopK { .. }) = (self.shed_p99_us, q) {
            if self.metrics.queries.load(Ordering::Relaxed) >= SHED_MIN_QUERIES
                && self.metrics.query_percentile_us(99.0) > th
            {
                self.metrics.query_shed();
                crate::obs::failstats::QUERIES_SHED.fetch_add(1, Ordering::Relaxed);
                return Answer::Shed;
            }
        }
        let _span = crate::obs::span(&crate::obs::QUERY);
        let t = std::time::Instant::now();
        let ans = match *q {
            Query::Corr { i, j } => Answer::Corr(self.corr(i, j)),
            Query::TopK { i, k } => Answer::TopK(self.top_k_routed(i, k)),
        };
        self.metrics.record_query(t.elapsed().as_nanos() as u64);
        ans
    }
}

/// One measured serving pass over a query workload — shared by the
/// `serving` bench group and `examples/ann_serve.rs` so both report
/// identically-defined numbers.
#[derive(Clone, Copy, Debug)]
pub struct ServingSample {
    /// Throughput of the one-at-a-time calibration pass (queries issued
    /// serially from the measuring thread; each query may still use an
    /// index built with a threaded `ExecPolicy`).
    pub qps_serial: f64,
    /// Throughput of a [`QueryBatch`] pass with the given worker count.
    pub qps_batch: f64,
    /// Per-query latency percentiles of the serial pass, read from the
    /// service's [`Metrics::query_hist`] delta window — exact on the
    /// histogram's log-bucket grid, not derived from the mean.
    pub p50_us: f64,
    pub p99_us: f64,
    /// Mean candidate rows scored per top-k query (metrics delta across
    /// both passes; NaN-free — 0 when the workload had no top-k queries).
    pub mean_candidates: f64,
}

/// Measure `queries` over `service`: a serial pass for latency
/// percentiles + serial QPS, then a batched pass for pool QPS.
///
/// Latency p50/p99 are taken from the delta of [`Metrics::query_hist`]
/// across the serial pass, so a service reused for several measured
/// workloads still reports per-window percentiles.
pub fn measure_serving(
    service: &SimilarityService,
    queries: &[Query],
    workers: usize,
) -> ServingSample {
    let before = service.metrics.snapshot();
    let hist_before = service.metrics.query_hist.snapshot();
    let t = crate::util::timer::Timer::start();
    for q in queries {
        std::hint::black_box(service.answer(q));
    }
    let qps_serial = queries.len() as f64 / t.elapsed_secs();
    let serial = service.metrics.query_hist.snapshot().sub(&hist_before);
    let t = crate::util::timer::Timer::start();
    let answers = QueryBatch::run(service, queries, workers);
    let qps_batch = answers.len() as f64 / t.elapsed_secs();
    let after = service.metrics.snapshot();
    let dq = (after.topk_queries - before.topk_queries).max(1);
    let mean_candidates =
        (after.candidates_scanned - before.candidates_scanned) as f64 / dq as f64;
    ServingSample {
        qps_serial,
        qps_batch,
        p50_us: serial.percentile(50.0) as f64 / 1e3,
        p99_us: serial.percentile(99.0) as f64 / 1e3,
        mean_candidates,
    }
}

/// A batch executor: fans queries out over the persistent `par` pool
/// (no per-batch thread spawns, no queue hand-off), preserving input
/// order in the answer vector. Sharing the pool with the kernels means
/// serving bursts and embedding jobs stop competing for oversubscribed
/// cores — the pool's one-wake-per-region scheduling arbitrates.
pub struct QueryBatch;

impl QueryBatch {
    /// Execute `queries` with `workers` pool threads over `service`.
    /// Answers land in input order; oversplitting gives dynamic load
    /// balance when query costs are skewed (top-k vs pairwise).
    pub fn run(service: &SimilarityService, queries: &[Query], workers: usize) -> Vec<Answer> {
        let exec = ExecPolicy::with_threads(workers.max(1));
        let ranges = par::even_ranges(queries.len(), exec.chunks(queries.len()));
        let mut answers: Vec<Option<Answer>> = queries.iter().map(|_| None).collect();
        exec.for_chunks(&ranges, &mut answers, 1, |_, r, out| {
            for (slot, qi) in out.iter_mut().zip(r) {
                *slot = Some(service.answer(&queries[qi]));
            }
        });
        answers.into_iter().map(|a| a.expect("missing answer")).collect()
    }

    /// Like [`QueryBatch::run`] but bounded by a wall-clock `deadline`:
    /// a [`CancelToken`] is polled before every query, so an
    /// over-deadline batch stops within one query's latency per worker
    /// and returns [`JobError::DeadlineExceeded`] with partial-progress
    /// stats instead of answers.
    pub fn run_with_deadline(
        service: &SimilarityService,
        queries: &[Query],
        workers: usize,
        deadline: Duration,
    ) -> Result<Vec<Answer>, JobError> {
        let started = Instant::now();
        let cancel = CancelToken::with_deadline(deadline);
        let exec = ExecPolicy::with_threads(workers.max(1));
        let ranges = par::even_ranges(queries.len(), exec.chunks(queries.len()));
        let mut answers: Vec<Option<Answer>> = queries.iter().map(|_| None).collect();
        exec.for_chunks(&ranges, &mut answers, 1, |_, r, out| {
            for (slot, qi) in out.iter_mut().zip(r) {
                if cancel.is_cancelled() {
                    return;
                }
                *slot = Some(service.answer(&queries[qi]));
            }
        });
        if cancel.is_cancelled() {
            crate::obs::failstats::DEADLINE_ABORTS.fetch_add(1, Ordering::Relaxed);
            service.metrics.deadline_aborts.fetch_add(1, Ordering::Relaxed);
            return Err(JobError::DeadlineExceeded {
                done: answers.iter().filter(|a| a.is_some()).count(),
                total: queries.len(),
                elapsed_ms: started.elapsed().as_millis() as u64,
            });
        }
        Ok(answers.into_iter().map(|a| a.expect("missing answer")).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{ExactIndex, SimHashIndex, SimHashParams};
    use crate::util::rng::Rng;

    fn service(n: usize, d: usize, seed: u64) -> SimilarityService {
        let mut rng = Rng::new(seed);
        SimilarityService::new(Mat::randn(&mut rng, n, d))
    }

    #[test]
    fn corr_agrees_with_mat_row_corr() {
        let s = service(20, 6, 221);
        for i in 0..20 {
            for j in 0..20 {
                assert!((s.corr(i, j) - s.e.row_corr(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn top_k_matches_exhaustive_sort() {
        let s = service(50, 5, 222);
        for &i in &[0, 7, 49] {
            let got = s.top_k(i, 5);
            let mut all: Vec<(usize, f64)> =
                (0..50).filter(|&j| j != i).map(|j| (j, s.corr(i, j))).collect();
            all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let want: Vec<usize> = all[..5].iter().map(|p| p.0).collect();
            let got_idx: Vec<usize> = got.iter().map(|p| p.0).collect();
            assert_eq!(got_idx, want, "top-k mismatch at {i}");
            // Scores descending.
            for w in got.windows(2) {
                assert!(w[0].1 >= w[1].1);
            }
        }
    }

    #[test]
    fn top_k_tie_break_is_by_vertex_id() {
        // Rows 1, 2, 3 are positive multiples of each other: corr with
        // row 0 ties at 1.0, and the lower ids must win in order.
        let e = Mat::from_rows(&[
            &[2.0, 0.0],
            &[1.0, 0.0],
            &[3.0, 0.0],
            &[5.0, 0.0],
            &[0.0, 1.0],
        ]);
        let s = SimilarityService::new(e);
        let got: Vec<usize> = s.top_k(0, 3).iter().map(|p| p.0).collect();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn top_k_k_larger_than_n() {
        let s = service(5, 3, 223);
        let got = s.top_k(0, 100);
        assert_eq!(got.len(), 4);
    }

    #[test]
    fn batch_preserves_order_and_matches_serial() {
        let s = service(40, 4, 224);
        let queries: Vec<Query> = (0..30)
            .map(|t| {
                if t % 2 == 0 {
                    Query::Corr { i: t % 40, j: (t * 7) % 40 }
                } else {
                    Query::TopK { i: t % 40, k: 3 }
                }
            })
            .collect();
        let serial: Vec<Answer> = queries.iter().map(|q| s.answer(q)).collect();
        let batched = QueryBatch::run(&s, &queries, 4);
        assert_eq!(serial, batched);
        assert!(s.metrics.snapshot().queries >= 60);
    }

    #[test]
    fn exact_index_routing_matches_scan_and_counts_candidates() {
        let mut s = service(60, 5, 225);
        let want: Vec<Answer> =
            (0..10).map(|i| Answer::TopK(s.top_k(i, 4))).collect();
        s.attach_index(Box::new(ExactIndex::new(60)));
        assert_eq!(s.index_name(), Some("exact"));
        for (i, w) in want.iter().enumerate() {
            assert_eq!(&s.answer(&Query::TopK { i, k: 4 }), w);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.topk_queries, 10);
        assert_eq!(snap.candidates_scanned, 10 * 59);
    }

    #[test]
    fn simhash_full_probe_routing_equals_exact() {
        let mut s = service(48, 6, 226);
        let want: Vec<Vec<(usize, f64)>> = (0..48).map(|i| s.top_k(i, 5)).collect();
        let idx = SimHashIndex::build(
            s.embedding(),
            SimHashParams { tables: 1, bits: 4, probes: 1 << 4, seed: 2, ..Default::default() },
        );
        s.attach_index(Box::new(idx));
        for (i, w) in want.iter().enumerate() {
            assert_eq!(s.answer(&Query::TopK { i, k: 5 }), Answer::TopK(w.clone()));
        }
        // Indexed path recorded its (full-coverage) candidate sets.
        assert_eq!(s.metrics.snapshot().candidates_scanned, 48 * 47);
        assert!(s.detach_index().is_some());
        assert_eq!(s.index_name(), None);
    }

    #[test]
    fn measure_serving_counts_and_sane_stats() {
        let s = service(30, 4, 227);
        let queries: Vec<Query> =
            (0..20).map(|i| Query::TopK { i: i % 30, k: 3 }).collect();
        let sample = measure_serving(&s, &queries, 2);
        // Serial + batched pass both ran every query exactly once.
        assert_eq!(s.metrics.snapshot().topk_queries, 40);
        assert!((sample.mean_candidates - 29.0).abs() < 1e-12);
        assert!(sample.qps_serial > 0.0 && sample.qps_batch > 0.0);
        // Histogram-backed percentiles: ordered and positive.
        assert!(sample.p50_us <= sample.p99_us);
        assert!(sample.p99_us > 0.0);
    }

    #[test]
    fn zero_row_corr_is_zero() {
        let mut e = Mat::zeros(3, 4);
        e.row_mut(1).copy_from_slice(&[1.0, 0.0, 0.0, 0.0]);
        let s = SimilarityService::new(e);
        assert_eq!(s.corr(0, 1), 0.0);
    }

    /// An index whose probe always panics — the fault the serving layer
    /// must isolate.
    struct PanickyIndex(usize);

    impl AnnIndex for PanickyIndex {
        fn name(&self) -> &'static str {
            "panicky"
        }
        fn len(&self) -> usize {
            self.0
        }
        fn top_k(&self, _e: &Mat, _norms: &[f64], _i: usize, _k: usize) -> crate::index::TopK {
            panic!("probe exploded");
        }
        fn mem_bytes(&self) -> usize {
            0
        }
    }

    /// An index that returns no candidates at all (a degenerate probe).
    struct EmptyIndex(usize);

    impl AnnIndex for EmptyIndex {
        fn name(&self) -> &'static str {
            "empty"
        }
        fn len(&self) -> usize {
            self.0
        }
        fn top_k(&self, _e: &Mat, _norms: &[f64], _i: usize, _k: usize) -> crate::index::TopK {
            crate::index::TopK { hits: Vec::new(), candidates: 0 }
        }
        fn mem_bytes(&self) -> usize {
            0
        }
    }

    #[test]
    fn panicking_index_falls_back_to_exact_scan() {
        let mut s = service(30, 4, 228);
        let want: Vec<_> = (0..5).map(|i| s.top_k(i, 3)).collect();
        s.attach_index(Box::new(PanickyIndex(30)));
        for (i, w) in want.iter().enumerate() {
            assert_eq!(s.answer(&Query::TopK { i, k: 3 }), Answer::TopK(w.clone()));
        }
        assert_eq!(s.metrics.snapshot().fallback_exact, 5);
        // The service stays usable after the panics.
        assert!(matches!(s.answer(&Query::Corr { i: 0, j: 1 }), Answer::Corr(_)));
    }

    #[test]
    fn empty_probe_falls_back_to_exact_scan() {
        let mut s = service(30, 4, 229);
        let want = s.top_k(2, 4);
        s.attach_index(Box::new(EmptyIndex(30)));
        assert_eq!(s.answer(&Query::TopK { i: 2, k: 4 }), Answer::TopK(want));
        assert_eq!(s.metrics.snapshot().fallback_exact, 1);
        // k = 0 legitimately has no hits: not a fallback.
        assert_eq!(s.answer(&Query::TopK { i: 2, k: 0 }), Answer::TopK(Vec::new()));
        assert_eq!(s.metrics.snapshot().fallback_exact, 1);
    }

    #[test]
    fn shed_threshold_rejects_topk_once_p99_crosses() {
        let mut s = service(20, 4, 230);
        s.set_shed_threshold(Some(0.0));
        // Below the minimum sample size nothing is shed.
        assert!(matches!(s.answer(&Query::TopK { i: 0, k: 2 }), Answer::TopK(_)));
        // Build up a latency sample with cheap pairwise queries.
        for t in 0..SHED_MIN_QUERIES {
            let a = s.answer(&Query::Corr { i: t % 20, j: (t + 1) % 20 });
            assert!(matches!(a, Answer::Corr(_)));
        }
        // p99 of any real workload is > 0.0 µs → top-k is shed now...
        assert_eq!(s.answer(&Query::TopK { i: 1, k: 2 }), Answer::Shed);
        assert!(s.metrics.snapshot().queries_shed >= 1);
        // ...while pairwise queries keep flowing,
        assert!(matches!(s.answer(&Query::Corr { i: 0, j: 1 }), Answer::Corr(_)));
        // and clearing the threshold restores top-k service.
        s.set_shed_threshold(None);
        assert!(matches!(s.answer(&Query::TopK { i: 1, k: 2 }), Answer::TopK(_)));
    }

    #[test]
    fn batch_deadline_zero_aborts_with_partial_progress() {
        let s = service(25, 4, 231);
        let queries: Vec<Query> = (0..40).map(|i| Query::TopK { i: i % 25, k: 3 }).collect();
        let err = QueryBatch::run_with_deadline(&s, &queries, 2, Duration::ZERO).unwrap_err();
        match err {
            JobError::DeadlineExceeded { done, total, .. } => {
                assert_eq!(total, 40);
                assert!(done < 40, "a zero deadline cannot finish the batch");
            }
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert!(s.metrics.snapshot().deadline_aborts >= 1);
        // A generous deadline answers everything, identically to run().
        let ok = QueryBatch::run_with_deadline(&s, &queries, 2, Duration::from_secs(600)).unwrap();
        assert_eq!(ok, QueryBatch::run(&s, &queries, 2));
    }
}
