//! Algorithm 1 (FASTEMBEDEIG) + §3.5 general-matrix embedding + §4
//! cascading, generic over [`Operator`] — so the driver is agnostic to
//! the sparse storage format behind the block products (CSR or
//! SELL-C-σ via `crate::sparse::SparseMat`, both bitwise-identical).

use super::norm::{spectral_norm, NormEstParams};
use super::omega::rademacher_omega;
use super::op::{Operator, ScaledOp};
use crate::funcs::SpectralFn;
use crate::linalg::Mat;
use crate::par::{ExecPolicy, Workspace};
use crate::poly::cascade::{self, CascadePlan};
use crate::poly::{chebyshev, legendre, Basis, Series};
use crate::sparse::{graph, Csr};
use crate::util::rng::Rng;

/// FastEmbed parameters.
#[derive(Clone, Debug)]
pub struct Params {
    /// Embedding dimension d; 0 → auto `ceil(6 log n)` (paper's choice).
    pub d: usize,
    /// Total matrix-vector budget L per starting vector.
    pub order: usize,
    /// Cascade factor b (§4); 1 disables cascading.
    pub cascade: usize,
    /// Polynomial basis (Legendre = paper default).
    pub basis: Basis,
    /// Spectral-norm estimation; `None` asserts ‖S‖ ≤ 1 already
    /// (e.g. normalized adjacencies).
    pub norm_est: Option<NormEstParams>,
    /// Intra-block-product threading for the recursion and the norm
    /// estimator. The embedding is bitwise-identical at any thread
    /// count; serial by default (the CLI plumbs `--threads` here).
    pub exec: ExecPolicy,
}

impl Default for Params {
    fn default() -> Self {
        Params {
            d: 0,
            order: 120,
            cascade: 2,
            basis: Basis::Legendre,
            norm_est: None,
            exec: ExecPolicy::serial(),
        }
    }
}

/// Result of an embedding run.
pub struct Embedding {
    /// n×d compressive embedding Ẽ; rows approximate rows of E up to
    /// Theorem 1's distortion.
    pub e: Mat,
    /// The cascade plan actually executed (stage series + b).
    pub plan: CascadePlan,
    /// ‖S‖ estimate used for rescaling (1.0 when `norm_est` is None).
    pub norm_estimate: f64,
    /// Total operator applications performed (L·(cascade stages)).
    pub matvecs: usize,
}

/// §3.5 output for general m×n matrices.
pub struct GeneralEmbedding {
    /// m×d embedding of the **rows** of A (≈ rows of [f(σ)u …]).
    pub rows: Mat,
    /// n×d embedding of the **columns** of A (≈ rows of [f(σ)v …]).
    pub cols: Mat,
    pub norm_estimate: f64,
    pub matvecs: usize,
}

/// The FastEmbed driver.
pub struct FastEmbed {
    pub params: Params,
}

impl FastEmbed {
    pub fn new(params: Params) -> Self {
        assert!(params.cascade >= 1, "cascade must be >= 1");
        assert!(params.order >= 1, "order must be >= 1");
        FastEmbed { params }
    }

    fn auto_d(&self, n: usize) -> usize {
        if self.params.d > 0 {
            self.params.d
        } else {
            (6.0 * (n.max(2) as f64).ln()).ceil() as usize
        }
    }

    /// Embed a symmetric operator with weighing function `f`.
    pub fn embed(&self, op: &(impl Operator + ?Sized), f: &SpectralFn, rng: &mut Rng) -> Embedding {
        let n = op.dim();
        let omega = rademacher_omega(rng, n, self.auto_d(n));
        self.embed_with_omega(op, f, omega, rng)
    }

    /// Embed with a caller-supplied Ω (deterministic tests; the
    /// coordinator shards Ω's columns across workers and calls this).
    pub fn embed_with_omega(
        &self,
        op: &(impl Operator + ?Sized),
        f: &SpectralFn,
        omega: Mat,
        rng: &mut Rng,
    ) -> Embedding {
        assert_eq!(omega.rows, op.dim(), "Ω row count must match operator");
        let exec = &self.params.exec;
        let kappa = match &self.params.norm_est {
            Some(pe) => spectral_norm(op, pe, rng, exec).max(1e-300),
            None => 1.0,
        };
        let plan = plan_scaled(f, kappa, self.params.order, self.params.cascade, self.params.basis);
        let scaled = ScaledOp::new(op, 1.0 / kappa, 0.0);
        let mut matvecs = 0;
        let mut ws = Workspace::new();
        let mut e = omega;
        for _ in 0..plan.b {
            let next = apply_series_ws(&scaled, &plan.stage, &e, &mut matvecs, exec, &mut ws);
            // Recycle the previous stage's block for the next one.
            ws.give_mat(e);
            e = next;
        }
        Embedding { e, plan, norm_estimate: kappa, matvecs }
    }

    /// §3.5: embed a general (possibly rectangular) matrix A through the
    /// symmetric dilation S = [[0, Aᵀ],[A, 0]] with the odd extension
    /// f'(x) = f(x)I(x≥0) − f(−x)I(x<0).
    ///
    /// Cascading is disabled on this path (the odd extension takes
    /// negative values, so a b-th-root stage function does not exist);
    /// the full `order` budget goes to a single stage.
    pub fn embed_general(&self, a: &Csr, f: &SpectralFn, rng: &mut Rng) -> GeneralEmbedding {
        let (m, n) = (a.rows, a.cols);
        let exec = &self.params.exec;
        let s = graph::dilation(a);
        let kappa = match &self.params.norm_est {
            Some(pe) => spectral_norm(&s, pe, rng, exec).max(1e-300),
            None => 1.0,
        };
        let series = odd_extension_series(f, kappa, self.params.order, self.params.basis);
        let scaled = ScaledOp::new(&s, 1.0 / kappa, 0.0);
        let omega = rademacher_omega(rng, m + n, self.auto_d(m + n));
        let mut matvecs = 0;
        let e_all = apply_series(&scaled, &series, &omega, &mut matvecs, exec);
        // First n rows ↔ columns of A, last m rows ↔ rows of A (§3.5).
        let d = e_all.cols;
        let mut cols = Mat::zeros(n, d);
        cols.data.copy_from_slice(&e_all.data[..n * d]);
        let mut rows = Mat::zeros(m, d);
        rows.data.copy_from_slice(&e_all.data[n * d..]);
        GeneralEmbedding { rows, cols, norm_estimate: kappa, matvecs }
    }
}

/// Evaluate `f̃(S)·Q₀` by the three-term recursion (Algorithm 1 lines
/// 5–8). Convenience wrapper over [`apply_series_ws`] with a throwaway
/// workspace — call sites that iterate (the cascade loop, coordinator
/// shard workers) should hold a [`Workspace`] and call the `_ws` form so
/// the blocks are recycled across calls.
pub fn apply_series(
    op: &(impl Operator + ?Sized),
    series: &Series,
    q0: &Mat,
    matvecs: &mut usize,
    exec: &ExecPolicy,
) -> Mat {
    let mut ws = Workspace::new();
    apply_series_ws(op, series, q0, matvecs, exec, &mut ws)
}

/// [`apply_series`] with all four blocks (result + three ping-pong
/// buffers) and the kernels' partition scratch drawn from `ws`: the
/// recursion's steady state performs **zero heap allocations** — per
/// iteration *and*, once the workspace is warm, per call. Give the
/// returned block back (`ws.give_mat`) when it stops being needed to
/// keep the cycle closed. `matvecs` counts *column* matvecs (one block
/// application of width w adds w), matching the paper's L·d accounting.
/// Each recurrence step is one **fused** pass
/// (`q_new = c1·S·q_prev − c2·q_prev2` via [`Operator::apply_axpby_into_ws`])
/// on `exec`'s persistent pool, so the scale-and-subtract recombination
/// no longer re-reads the output block; only the coefficient axpy into
/// the accumulator remains a separate (serial, memory-bound) sweep.
/// The kernels' row/slice partition lists are sticky in `ws` (keyed on
/// the operator's prefix array and thread count), so steady-state
/// iterations skip the partition scan entirely.
pub fn apply_series_ws(
    op: &(impl Operator + ?Sized),
    series: &Series,
    q0: &Mat,
    matvecs: &mut usize,
    exec: &ExecPolicy,
    ws: &mut Workspace,
) -> Mat {
    let a = &series.coeffs;
    assert!(!a.is_empty(), "empty series");
    let _span = crate::obs::span(&crate::obs::APPLY_SERIES);
    let mut e = ws.take_mat(q0.rows, q0.cols);
    e.data.copy_from_slice(&q0.data);
    e.scale(a[0]);
    if a.len() == 1 {
        return e;
    }
    // q1 = S q0 (p(1, x) = x in both bases).
    let mut q_prev2 = ws.take_mat(q0.rows, q0.cols);
    q_prev2.data.copy_from_slice(&q0.data);
    let mut q_prev = ws.take_mat(q0.rows, q0.cols);
    op.apply_into_ws(q0, &mut q_prev, exec, ws);
    *matvecs += q0.cols;
    e.axpy(a[1], &q_prev);
    let mut q_new = ws.take_mat(q0.rows, q0.cols);
    for r in 2..a.len() {
        // Cancellation checkpoint (deadline/cancel plumbed through the
        // workspace): bail between recurrence steps, retire the buffers
        // normally, and return the partial accumulator — the caller that
        // observed cancellation discards it.
        if ws.cancelled() {
            break;
        }
        let (c1, c2) = series.recursion_scalars(r);
        // q_new = c1 * S q_prev − c2 * q_prev2, in one fused output pass.
        // (`alpha·t + (−c2)·z` is the same IEEE expression as
        // `c1·t − c2·z`, so fusing does not move any bits.)
        op.apply_axpby_into_ws(&q_prev, c1, -c2, &q_prev2, &mut q_new, exec, ws);
        *matvecs += q0.cols;
        e.axpy(a[r], &q_new);
        // Rotate buffers: prev2 <- prev <- new (reuse prev2's storage).
        std::mem::swap(&mut q_prev2, &mut q_prev);
        std::mem::swap(&mut q_prev, &mut q_new);
    }
    // Retire the ping-pong blocks; the next call recycles them.
    ws.give_mat(q_prev2);
    ws.give_mat(q_prev);
    ws.give_mat(q_new);
    e
}

/// Build the cascade plan for f on an operator rescaled by 1/kappa:
/// the stage approximates g(x) = f(kappa·x)^{1/b} on [-1, 1].
/// Indicators transport exactly (closed form); general f is fit by
/// quadrature on the transported closure.
pub fn plan_scaled(f: &SpectralFn, kappa: f64, order: usize, b: usize, basis: Basis) -> CascadePlan {
    debug_assert!(kappa > 0.0);
    if (kappa - 1.0).abs() < 1e-15 {
        return cascade::plan(f, order, b, basis);
    }
    // Exact transport for indicators.
    let transported = match f {
        SpectralFn::Step { c } => Some(SpectralFn::Step { c: c / kappa }),
        SpectralFn::Band { a, b: hi } => Some(SpectralFn::Band { a: a / kappa, b: hi / kappa }),
        _ => None,
    };
    if let Some(t) = transported {
        return cascade::plan(&t, order, b, basis);
    }
    let stage_order = (order / b).max(1);
    let g = |x: f64| crate::poly::cascade::nth_root_nonneg(f.eval(kappa * x).max(0.0), b);
    let stage = match basis {
        Basis::Legendre => legendre::fit(g, stage_order, 512),
        Basis::Chebyshev => chebyshev::fit(g, stage_order, 8192),
    };
    CascadePlan { stage, b }
}

/// Series for the §3.5 odd extension f'(x) = f(x)I(x≥0) − f(−x)I(x<0) on
/// the 1/kappa-rescaled spectrum. Step/Band get exact coefficients as a
/// difference of indicators; general f is fit by quadrature.
pub fn odd_extension_series(f: &SpectralFn, kappa: f64, order: usize, basis: Basis) -> Series {
    match (f, basis) {
        (SpectralFn::Step { c }, Basis::Legendre) => {
            let c = (c / kappa).max(0.0);
            let pos = legendre::indicator_coeffs(order, c, 1.0);
            let neg = legendre::indicator_coeffs(order, -1.0, -c);
            Series {
                basis,
                coeffs: pos.coeffs.iter().zip(&neg.coeffs).map(|(p, n)| p - n).collect(),
            }
        }
        (SpectralFn::Band { a, b: hi }, Basis::Legendre) => {
            let (a, hi) = ((a / kappa).max(0.0), (hi / kappa).max(0.0));
            let pos = legendre::indicator_coeffs(order, a, hi);
            let neg = legendre::indicator_coeffs(order, -hi, -a);
            Series {
                basis,
                coeffs: pos.coeffs.iter().zip(&neg.coeffs).map(|(p, n)| p - n).collect(),
            }
        }
        _ => {
            let g = |x: f64| {
                if x >= 0.0 {
                    f.eval(kappa * x)
                } else {
                    -f.eval(-kappa * x)
                }
            };
            match basis {
                Basis::Legendre => legendre::fit(g, order, 512),
                Basis::Chebyshev => chebyshev::fit(g, order, 8192),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::linalg::eigh::jacobi_eigh;
    use crate::sparse::coo::Coo;
    use crate::testing::prop::{check, forall};

    /// Dense oracle: E' = f̃(S) Ω via eigendecomposition of S.
    fn oracle(s: &Mat, omega: &Mat, eval: impl Fn(f64) -> f64) -> Mat {
        let (lam, v) = jacobi_eigh(s);
        let mut vt_o = v.tmatmul(omega);
        for (i, &l) in lam.iter().enumerate() {
            let fl = eval(l);
            for j in 0..vt_o.cols {
                vt_o[(i, j)] *= fl;
            }
        }
        v.matmul(&vt_o)
    }

    fn random_sym(rng: &mut Rng, n: usize) -> Mat {
        let mut a = Mat::randn(rng, n, n);
        for i in 0..n {
            for j in 0..i {
                let v = (a[(i, j)] + a[(j, i)]) / 2.0;
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let (lam, _) = jacobi_eigh(&a);
        let norm = lam.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-9);
        a.scale(1.0 / norm);
        a
    }

    #[test]
    fn apply_series_matches_matrix_polynomial_oracle() {
        forall(
            141,
            8,
            |r| {
                let n = 4 + r.below(8);
                (random_sym(r, n), Mat::randn(r, n, 5))
            },
            |(s, omega)| {
                // A smooth function fit to low order: recursion output must
                // equal the eigen-space evaluation of the same polynomial.
                let series = legendre::fit(|x| (1.5 * x).exp(), 10, 64);
                let mut mv = 0;
                let exec = ExecPolicy::serial();
                let got = apply_series(&DenseOp(s.clone()), &series, omega, &mut mv, &exec);
                let want = oracle(s, omega, |x| series.eval(x));
                check(mv == 10 * omega.cols, format!("matvec count {mv}"))?;
                check(
                    got.max_abs_diff(&want) < 1e-9,
                    format!("recursion vs oracle: {}", got.max_abs_diff(&want)),
                )
            },
        );
    }

    #[test]
    fn apply_series_chebyshev_basis_agrees_too() {
        let mut rng = Rng::new(142);
        let s = random_sym(&mut rng, 9);
        let omega = Mat::randn(&mut rng, 9, 4);
        let series = chebyshev::fit(|x| 0.5 + x * x, 6, 512);
        let mut mv = 0;
        let exec = ExecPolicy::serial();
        let got = apply_series(&DenseOp(s.clone()), &series, &omega, &mut mv, &exec);
        let want = oracle(&s, &omega, |x| series.eval(x));
        assert!(got.max_abs_diff(&want) < 1e-9);
    }

    #[test]
    fn order_zero_and_one() {
        let mut rng = Rng::new(143);
        let s = random_sym(&mut rng, 6);
        let omega = Mat::randn(&mut rng, 6, 3);
        let mut mv = 0;
        let s0 = Series { basis: Basis::Legendre, coeffs: vec![2.0] };
        let e0 = apply_series(&DenseOp(s.clone()), &s0, &omega, &mut mv, &ExecPolicy::serial());
        let mut want0 = omega.clone();
        want0.scale(2.0);
        assert!(e0.max_abs_diff(&want0) < 1e-14);
        assert_eq!(mv, 0);

        let s1 = Series { basis: Basis::Legendre, coeffs: vec![0.5, -1.0] };
        let e1 = apply_series(&DenseOp(s.clone()), &s1, &omega, &mut mv, &ExecPolicy::serial());
        let mut want1 = omega.clone();
        want1.scale(0.5);
        want1.axpy(-1.0, &s.matmul(&omega));
        assert!(e1.max_abs_diff(&want1) < 1e-12);
        assert_eq!(mv, 3); // one block application of 3 columns
    }

    #[test]
    fn embed_approximates_exact_spectral_embedding_distances() {
        // End-to-end Theorem 1 check on a small dense matrix with a clean
        // spectral gap: pairwise distances of Ẽ ≈ those of E within
        // JL ± polynomial distortion.
        let mut rng = Rng::new(144);
        let n = 24;
        // Matrix with 4 eigenvalues near 1, rest spread in [-0.4, 0.4].
        let q = {
            let mut m = Mat::randn(&mut rng, n, n);
            crate::linalg::qr::mgs_orthonormalize(&mut m, 1e-12);
            m
        };
        let mut lam = vec![0.0; n];
        for (i, l) in lam.iter_mut().enumerate() {
            *l = if i < 4 { 0.96 + 0.01 * i as f64 } else { -0.4 + 0.8 * (i as f64 / n as f64) };
        }
        let mut s = Mat::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                let mut acc = 0.0;
                for t in 0..n {
                    acc += q[(r, t)] * lam[t] * q[(c, t)];
                }
                s[(r, c)] = acc;
            }
        }
        let f = SpectralFn::Step { c: 0.9 };
        let fe = FastEmbed::new(Params { d: 96, order: 80, cascade: 2, ..Params::default() });
        let emb = fe.embed(&DenseOp(s.clone()), &f, &mut rng);
        assert_eq!(emb.matvecs, 80 * 96); // L column-chains of d = 96
        // Exact embedding distances = distances between rows of f(S).
        let exact = oracle(&s, &Mat::eye(n), |x| f.eval(x));
        let mut worst: f64 = 0.0;
        for i in 0..n {
            for j in 0..i {
                let de = exact.row_dist(i, &exact, j);
                let dg = emb.e.row_dist(i, &emb.e, j);
                worst = worst.max((dg - de).abs());
            }
        }
        // Additive distortion delta*sqrt(2) + JL eps; generous bound.
        assert!(worst < 0.35, "worst distance deviation {worst}");
    }

    #[test]
    fn norm_estimation_rescales_unnormalized_operators() {
        // Same matrix scaled by 10 with threshold scaled by 10 must give
        // (nearly) the same embedding when norm_est is enabled.
        let mut rng = Rng::new(145);
        let s = random_sym(&mut rng, 12);
        let omega = rademacher_omega(&mut rng, 12, 32);
        let f1 = SpectralFn::Step { c: 0.5 };
        let fe_plain = FastEmbed::new(Params { d: 32, order: 40, cascade: 1, ..Params::default() });
        let e1 = fe_plain.embed_with_omega(&DenseOp(s.clone()), &f1, omega.clone(), &mut rng);

        let mut s10 = s.clone();
        s10.scale(10.0);
        let f10 = SpectralFn::Step { c: 5.0 };
        let fe_est = FastEmbed::new(Params {
            d: 32,
            order: 40,
            cascade: 1,
            norm_est: Some(NormEstParams { iters: 60, ..Default::default() }),
            ..Params::default()
        });
        let e10 = fe_est.embed_with_omega(&DenseOp(s10), &f10, omega, &mut rng);
        assert!((e10.norm_estimate / 10.0 - 1.0).abs() < 0.02);
        // Threshold in rescaled units differs by ~1% (norm safety factor);
        // embeddings agree closely since the spectrum has a gap at 0.5.
        assert!(
            e1.e.max_abs_diff(&e10.e) < 0.2,
            "rescale mismatch {}",
            e1.e.max_abs_diff(&e10.e)
        );
    }

    #[test]
    fn general_matrix_embedding_matches_svd_oracle() {
        // Rectangular A: check row/col embeddings against the dense SVD
        // computed through the dilation's eigendecomposition.
        let mut rng = Rng::new(146);
        let (m, n) = (10, 7);
        let mut coo = Coo::new(m, n);
        for _ in 0..30 {
            coo.push(rng.below(m), rng.below(n), rng.normal() * 0.3);
        }
        let a = Csr::from_coo(&coo);
        let f = SpectralFn::Step { c: 0.4 };
        let fe = FastEmbed::new(Params {
            d: 64,
            order: 60,
            cascade: 1,
            norm_est: Some(NormEstParams { iters: 60, ..Default::default() }),
            ..Params::default()
        });
        let ge = fe.embed_general(&a, &f, &mut rng);
        assert_eq!(ge.rows.rows, m);
        assert_eq!(ge.cols.rows, n);
        // Oracle: f'(S/kappa) with S the dilation.
        let s = graph::dilation(&a).to_dense();
        let kappa = ge.norm_estimate;
        let mut s_scaled = s.clone();
        s_scaled.scale(1.0 / kappa);
        let fo = |x: f64| {
            if x >= 0.0 {
                f.eval(kappa * x)
            } else {
                -f.eval(-kappa * x)
            }
        };
        let exact = oracle(&s_scaled, &Mat::eye(m + n), fo);
        // Distances between rows of A's row-embedding vs oracle's last m rows.
        let mut worst: f64 = 0.0;
        for i in 0..m {
            for j in 0..i {
                let de = exact.row_dist(n + i, &exact, n + j);
                let dg = ge.rows.row_dist(i, &ge.rows, j);
                worst = worst.max((dg - de).abs());
            }
        }
        assert!(worst < 0.4, "general embed worst deviation {worst}");
    }

    #[test]
    fn plan_scaled_transports_step_threshold() {
        let p = plan_scaled(&SpectralFn::Step { c: 0.8 }, 2.0, 40, 2, Basis::Legendre);
        // Stage approximates I(x >= 0.4) on [-1, 1].
        assert!((p.stage.eval(0.9) - 1.0).abs() < 0.1);
        assert!(p.stage.eval(0.0).abs() < 0.15);
    }

    #[test]
    fn odd_extension_series_is_odd() {
        let s = odd_extension_series(&SpectralFn::Step { c: 0.5 }, 1.0, 60, Basis::Legendre);
        for &x in &[0.1, 0.3, 0.7, 0.95] {
            assert!(
                (s.eval(x) + s.eval(-x)).abs() < 1e-10,
                "not odd at {x}: {} vs {}",
                s.eval(x),
                s.eval(-x)
            );
        }
        assert!((s.eval(0.8) - 1.0).abs() < 0.1);
        assert!((s.eval(-0.8) + 1.0).abs() < 0.1);
    }
}
