//! The operator abstraction iterated by the recursion.
//!
//! FastEmbed only ever touches the matrix through block products `S·Q`
//! (paper's key structural property), so the driver is generic over
//! [`Operator`]. Implementations here: CSR and SELL-C-σ (the scalable
//! native paths, interchangeable bit-for-bit), [`SparseMat`] (the
//! format-choice wrapper the CLI builds, carrying the autotuner's
//! kernel configuration), dense (oracles/tests), and an affine wrapper
//! for §3.4 spectrum rescaling. `crate::runtime::PjrtOp` adds the
//! AOT/PJRT tile path.
//!
//! Every application takes an [`ExecPolicy`]: the block product is the
//! parallelizable unit (the paper's "parallel across starting vectors",
//! realized here as row-range parallelism), and implementations must be
//! deterministic — output bitwise-independent of `exec.threads`.

use crate::linalg::Mat;
use crate::par::{self, ExecPolicy, Workspace};
use crate::sparse::{Csr, SellCs, SparseMat};

/// A symmetric linear operator usable by the recursion.
pub trait Operator {
    /// Dimension n (operator is n×n).
    fn dim(&self) -> usize;

    /// `y ← S x` for a block `x` (n×d). Must not allocate per call beyond
    /// what the implementation needs internally, and must produce output
    /// bitwise-independent of `exec.threads`.
    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy);

    /// `y ← S x` with internal scratch (partition lists, …) drawn from
    /// `ws` so steady-state iteration loops allocate nothing. Must be
    /// bitwise-identical to [`Self::apply_into`]; the default ignores
    /// the workspace.
    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        let _ = ws;
        self.apply_into(x, y, exec);
    }

    /// Fused affine application `y ← alpha·(S x) + beta·z`, in as few
    /// output passes as the implementation allows. `z` must have `y`'s
    /// shape (and not alias it); it is only read when `beta != 0`.
    ///
    /// The contract pins the write-back expression so fused and fallback
    /// paths agree bitwise: every output element is
    /// `alpha·(S x)[i] + beta·z[i]`, with the `beta` term skipped when
    /// `beta == 0` and the `alpha` scale skipped when additionally
    /// `alpha == 1`. Like the plain applies, the result must be
    /// bitwise-independent of `exec.threads`. The default falls back to
    /// [`Self::apply_into_ws`] plus one elementwise pass; CSR fuses the
    /// write-back into the SpMM kernel so each recurrence iteration
    /// touches the output exactly once.
    #[allow(clippy::too_many_arguments)]
    fn apply_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        assert_eq!((z.rows, z.cols), (y.rows, y.cols), "z must match the output shape");
        self.apply_into_ws(x, y, exec, ws);
        if beta != 0.0 {
            for (yv, zv) in y.data.iter_mut().zip(&z.data) {
                *yv = alpha * *yv + beta * zv;
            }
        } else if alpha != 1.0 {
            y.scale(alpha);
        }
    }

    /// Convenience allocating form.
    fn apply(&self, x: &Mat, exec: &ExecPolicy) -> Mat {
        let mut y = Mat::zeros(self.dim(), x.cols);
        self.apply_into(x, &mut y, exec);
        y
    }

    /// Number of stored non-zeros (T in the paper's complexity bounds);
    /// used for flop accounting and bench reporting.
    fn nnz(&self) -> usize;
}

impl Operator for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "operator must be square");
        self.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        self.spmm_into_with(x, y, exec);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.spmm_into_ws(x, y, exec, ws);
    }

    fn apply_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        self.spmm_axpby_into_ws(x, alpha, beta, z, y, exec, ws);
    }

    fn nnz(&self) -> usize {
        Csr::nnz(self)
    }
}

impl Operator for SellCs {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "operator must be square");
        self.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        let mut ws = Workspace::new();
        self.spmm_into_ws(x, y, exec, &mut ws);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.spmm_into_ws(x, y, exec, ws);
    }

    fn apply_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        self.spmm_axpby_into_ws(x, alpha, beta, z, y, exec, ws);
    }

    fn nnz(&self) -> usize {
        SellCs::nnz(self)
    }
}

/// The format-choice wrapper: whichever backend `--format`/the
/// autotuner picked, the products are bitwise-identical, so solvers and
/// the coordinator stay format-agnostic.
impl Operator for SparseMat {
    fn dim(&self) -> usize {
        assert_eq!(self.rows(), self.cols(), "operator must be square");
        self.rows()
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        let mut ws = Workspace::new();
        self.spmm_into_ws(x, y, exec, &mut ws);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.spmm_into_ws(x, y, exec, ws);
    }

    fn apply_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        self.spmm_axpby_into_ws(x, alpha, beta, z, y, exec, ws);
    }

    fn nnz(&self) -> usize {
        SparseMat::nnz(self)
    }
}

/// Dense symmetric operator (tests and small oracles). Parallelizes over
/// output-row ranges with the same per-row float order as `Mat::matmul`,
/// so results are bitwise-identical at any thread count.
pub struct DenseOp(pub Mat);

impl DenseOp {
    /// Row-chunked dense product with the fused write-back: accumulate a
    /// row of `S·x` in place, then rewrite it as `alpha·row + beta·z_row`
    /// while it is still cache-hot — the same float expression as the
    /// trait's fallback and the CSR kernel, so all paths match bitwise.
    fn axpby_chunks(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: Option<&Mat>,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        assert_eq!(x.rows, self.0.cols, "dense apply shape mismatch");
        assert_eq!((y.rows, y.cols), (self.0.rows, x.cols));
        let d = x.cols;
        let mut ranges = std::mem::take(&mut ws.ranges);
        par::even_ranges_into(self.0.rows, exec.chunks(self.0.rows), &mut ranges);
        exec.for_chunks(&ranges, &mut y.data, d, |_, rows, out| {
            out.fill(0.0);
            for (local, i) in rows.enumerate() {
                let arow = self.0.row(i);
                let orow = &mut out[local * d..(local + 1) * d];
                for (k, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = x.row(k);
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += aik * b;
                    }
                }
                if beta != 0.0 {
                    let zrow = z.expect("beta != 0 requires z").row(i);
                    for (o, &zv) in orow.iter_mut().zip(zrow) {
                        *o = alpha * *o + beta * zv;
                    }
                } else if alpha != 1.0 {
                    for o in orow.iter_mut() {
                        *o = alpha * *o;
                    }
                }
            }
        });
        ws.ranges = ranges;
    }
}

impl Operator for DenseOp {
    fn dim(&self) -> usize {
        assert_eq!(self.0.rows, self.0.cols);
        self.0.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        let mut ws = Workspace::new();
        self.apply_into_ws(x, y, exec, &mut ws);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.axpby_chunks(x, 1.0, 0.0, None, y, exec, ws);
    }

    fn apply_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        assert_eq!((z.rows, z.cols), (y.rows, y.cols), "z must match the output shape");
        self.axpby_chunks(x, alpha, beta, Some(z), y, exec, ws);
    }

    fn nnz(&self) -> usize {
        self.0.rows * self.0.cols
    }
}

/// Affine spectrum rescale `S' = alpha·S + beta·I` (paper §3.4) without
/// materializing a second matrix.
pub struct ScaledOp<'a, O: Operator + ?Sized> {
    pub inner: &'a O,
    pub alpha: f64,
    pub beta: f64,
}

impl<'a, O: Operator + ?Sized> ScaledOp<'a, O> {
    pub fn new(inner: &'a O, alpha: f64, beta: f64) -> Self {
        ScaledOp { inner, alpha, beta }
    }
}

impl<O: Operator + ?Sized> Operator for ScaledOp<'_, O> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        let mut ws = Workspace::new();
        self.apply_into_ws(x, y, exec, &mut ws);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        // `(a·S + b·I)x` is exactly the fused form with z = x, so the
        // whole affine rescale is one pass over the output instead of an
        // apply plus separate scale and axpy sweeps.
        self.inner.apply_axpby_into_ws(x, self.alpha, self.beta, x, y, exec, ws);
    }

    fn apply_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        if self.beta == 0.0 {
            // alpha·(a·(S x)) + beta·z = (alpha·a)·(S x) + beta·z: fold
            // the scalars and keep the single fused pass. This is the hot
            // case — §3.4 rescaling wraps operators as `a·S + 0·I`, so
            // the whole recurrence iteration stays one output pass.
            self.inner.apply_axpby_into_ws(x, alpha * self.alpha, beta, z, y, exec, ws);
        } else {
            // General affine-inside-affine (3 distinct terms): compute
            // S'x fused, then one elementwise pass for the outer axpby.
            assert_eq!((z.rows, z.cols), (y.rows, y.cols), "z must match the output shape");
            self.inner.apply_axpby_into_ws(x, self.alpha, self.beta, x, y, exec, ws);
            if beta != 0.0 {
                for (yv, zv) in y.data.iter_mut().zip(&z.data) {
                    *yv = alpha * *yv + beta * zv;
                }
            } else if alpha != 1.0 {
                y.scale(alpha);
            }
        }
    }

    fn nnz(&self) -> usize {
        self.inner.nnz() + self.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::testing::prop::{all_close, check, forall};
    use crate::util::rng::Rng;

    fn random_sym_csr(rng: &mut Rng, n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            coo.push_sym(i.min(j), i.max(j), rng.normal());
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn csr_and_dense_ops_agree() {
        forall(
            121,
            16,
            |r| {
                let n = 3 + r.below(10);
                (random_sym_csr(r, n), Mat::randn(r, n, 4))
            },
            |(a, x)| {
                let exec = ExecPolicy::serial();
                let dense = DenseOp(a.to_dense());
                all_close(
                    &Operator::apply(a, x, &exec).data,
                    &dense.apply(x, &exec).data,
                    1e-12,
                )
            },
        );
    }

    #[test]
    fn scaled_op_is_affine() {
        forall(
            122,
            16,
            |r| {
                let n = 3 + r.below(8);
                (
                    random_sym_csr(r, n),
                    Mat::randn(r, n, 3),
                    r.uniform(-2.0, 2.0),
                    r.uniform(-2.0, 2.0),
                )
            },
            |(a, x, alpha, beta)| {
                let exec = ExecPolicy::serial();
                let s = ScaledOp::new(a, *alpha, *beta);
                let got = s.apply(x, &exec);
                let mut want = Operator::apply(a, x, &exec);
                want.scale(*alpha);
                want.axpy(*beta, x);
                all_close(&got.data, &want.data, 1e-12)
            },
        );
    }

    #[test]
    fn scaled_identity_coefficients() {
        let a = Csr::eye(5);
        let s = ScaledOp::new(&a, 2.0, -0.5);
        let x = Mat::eye(5);
        let y = s.apply(&x, &ExecPolicy::serial());
        for i in 0..5 {
            assert!((y[(i, i)] - 1.5).abs() < 1e-14);
        }
    }

    #[test]
    fn fused_axpby_agrees_across_operators_and_threads() {
        forall(
            124,
            10,
            |r| {
                let n = 8 + r.below(40);
                (
                    random_sym_csr(r, n),
                    Mat::randn(r, n, 5),
                    Mat::randn(r, n, 5),
                    r.uniform(-2.0, 2.0),
                    r.uniform(-2.0, 2.0),
                )
            },
            |(a, x, z, alpha, beta)| {
                let serial = ExecPolicy::serial();
                let mut ws = Workspace::new();
                // Reference: the trait's pinned write-back expression over
                // a plain apply.
                let mut want = Operator::apply(a, x, &serial);
                for (yv, zv) in want.data.iter_mut().zip(&z.data) {
                    *yv = alpha * *yv + beta * zv;
                }
                let mut got = Mat::zeros(a.rows, x.cols);
                a.apply_axpby_into_ws(x, *alpha, *beta, z, &mut got, &serial, &mut ws);
                check(got.data == want.data, "csr fused != fallback expression")?;
                let dense = DenseOp(a.to_dense());
                let mut dgot = Mat::zeros(a.rows, x.cols);
                dense.apply_axpby_into_ws(x, *alpha, *beta, z, &mut dgot, &serial, &mut ws);
                all_close(&dgot.data, &want.data, 1e-12)?;
                for threads in [2usize, 4] {
                    let exec = ExecPolicy::with_threads(threads);
                    let mut yt = Mat::zeros(a.rows, x.cols);
                    a.apply_axpby_into_ws(x, *alpha, *beta, z, &mut yt, &exec, &mut ws);
                    check(yt.data == got.data, format!("csr fused differs at {threads} threads"))?;
                    let mut dt = Mat::zeros(a.rows, x.cols);
                    dense.apply_axpby_into_ws(x, *alpha, *beta, z, &mut dt, &exec, &mut ws);
                    let dmsg = format!("dense fused differs at {threads} threads");
                    check(dt.data == dgot.data, dmsg)?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn scaled_op_fused_general_case_matches_composition() {
        let mut rng = Rng::new(125);
        let a = random_sym_csr(&mut rng, 20);
        let x = Mat::randn(&mut rng, 20, 4);
        let z = Mat::randn(&mut rng, 20, 4);
        let exec = ExecPolicy::serial();
        let mut ws = Workspace::new();
        for (sa, sb, alpha, beta) in
            [(0.7, -0.3, 1.5, -0.25), (0.7, -0.3, 1.5, 0.0), (0.9, 0.0, 2.0, -1.0)]
        {
            let s = ScaledOp::new(&a, sa, sb);
            let mut got = Mat::zeros(20, 4);
            s.apply_axpby_into_ws(&x, alpha, beta, &z, &mut got, &exec, &mut ws);
            let mut want = s.apply(&x, &exec);
            for (yv, zv) in want.data.iter_mut().zip(&z.data) {
                *yv = alpha * *yv + beta * zv;
            }
            all_close(&got.data, &want.data, 1e-12)
                .unwrap_or_else(|e| panic!("scaled fused ({sa},{sb},{alpha},{beta}): {e:?}"));
        }
    }

    #[test]
    fn all_operators_are_thread_count_invariant() {
        forall(
            123,
            10,
            |r| {
                let n = 8 + r.below(40);
                (random_sym_csr(r, n), Mat::randn(r, n, 5))
            },
            |(a, x)| {
                let serial = ExecPolicy::serial();
                let want_csr = Operator::apply(a, x, &serial);
                let dense = DenseOp(a.to_dense());
                let want_dense = dense.apply(x, &serial);
                let scaled = ScaledOp::new(a, -0.7, 0.3);
                let want_scaled = scaled.apply(x, &serial);
                for threads in [2usize, 4] {
                    let exec = ExecPolicy::with_threads(threads);
                    check(
                        Operator::apply(a, x, &exec).data == want_csr.data,
                        format!("csr op differs at {threads} threads"),
                    )?;
                    check(
                        dense.apply(x, &exec).data == want_dense.data,
                        format!("dense op differs at {threads} threads"),
                    )?;
                    check(
                        scaled.apply(x, &exec).data == want_scaled.data,
                        format!("scaled op differs at {threads} threads"),
                    )?;
                }
                Ok(())
            },
        );
    }
}
