//! The operator abstraction iterated by the recursion.
//!
//! FastEmbed only ever touches the matrix through block products `S·Q`
//! (paper's key structural property), so the driver is generic over
//! [`Operator`]. Implementations here: CSR (the scalable native path),
//! dense (oracles/tests), and an affine wrapper for §3.4 spectrum
//! rescaling. `crate::runtime::PjrtOp` adds the AOT/PJRT tile path.
//!
//! Every application takes an [`ExecPolicy`]: the block product is the
//! parallelizable unit (the paper's "parallel across starting vectors",
//! realized here as row-range parallelism), and implementations must be
//! deterministic — output bitwise-independent of `exec.threads`.

use crate::linalg::Mat;
use crate::par::{self, ExecPolicy, Workspace};
use crate::sparse::Csr;

/// A symmetric linear operator usable by the recursion.
pub trait Operator {
    /// Dimension n (operator is n×n).
    fn dim(&self) -> usize;

    /// `y ← S x` for a block `x` (n×d). Must not allocate per call beyond
    /// what the implementation needs internally, and must produce output
    /// bitwise-independent of `exec.threads`.
    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy);

    /// `y ← S x` with internal scratch (partition lists, …) drawn from
    /// `ws` so steady-state iteration loops allocate nothing. Must be
    /// bitwise-identical to [`Self::apply_into`]; the default ignores
    /// the workspace.
    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        let _ = ws;
        self.apply_into(x, y, exec);
    }

    /// Convenience allocating form.
    fn apply(&self, x: &Mat, exec: &ExecPolicy) -> Mat {
        let mut y = Mat::zeros(self.dim(), x.cols);
        self.apply_into(x, &mut y, exec);
        y
    }

    /// Number of stored non-zeros (T in the paper's complexity bounds);
    /// used for flop accounting and bench reporting.
    fn nnz(&self) -> usize;
}

impl Operator for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "operator must be square");
        self.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        self.spmm_into_with(x, y, exec);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.spmm_into_ws(x, y, exec, ws);
    }

    fn nnz(&self) -> usize {
        Csr::nnz(self)
    }
}

/// Dense symmetric operator (tests and small oracles). Parallelizes over
/// output-row ranges with the same per-row float order as `Mat::matmul`,
/// so results are bitwise-identical at any thread count.
pub struct DenseOp(pub Mat);

impl Operator for DenseOp {
    fn dim(&self) -> usize {
        assert_eq!(self.0.rows, self.0.cols);
        self.0.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        let mut ws = Workspace::new();
        self.apply_into_ws(x, y, exec, &mut ws);
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        assert_eq!(x.rows, self.0.cols, "dense apply shape mismatch");
        assert_eq!((y.rows, y.cols), (self.0.rows, x.cols));
        let d = x.cols;
        let mut ranges = std::mem::take(&mut ws.ranges);
        par::even_ranges_into(self.0.rows, exec.chunks(self.0.rows), &mut ranges);
        exec.for_chunks(&ranges, &mut y.data, d, |_, rows, out| {
            out.fill(0.0);
            for (local, i) in rows.enumerate() {
                let arow = self.0.row(i);
                let orow = &mut out[local * d..(local + 1) * d];
                for (k, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = x.row(k);
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += aik * b;
                    }
                }
            }
        });
        ws.ranges = ranges;
    }

    fn nnz(&self) -> usize {
        self.0.rows * self.0.cols
    }
}

/// Affine spectrum rescale `S' = alpha·S + beta·I` (paper §3.4) without
/// materializing a second matrix.
pub struct ScaledOp<'a, O: Operator + ?Sized> {
    pub inner: &'a O,
    pub alpha: f64,
    pub beta: f64,
}

impl<'a, O: Operator + ?Sized> ScaledOp<'a, O> {
    pub fn new(inner: &'a O, alpha: f64, beta: f64) -> Self {
        ScaledOp { inner, alpha, beta }
    }
}

impl<O: Operator + ?Sized> Operator for ScaledOp<'_, O> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        self.inner.apply_into(x, y, exec);
        if self.alpha != 1.0 {
            y.scale(self.alpha);
        }
        if self.beta != 0.0 {
            y.axpy(self.beta, x);
        }
    }

    fn apply_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.inner.apply_into_ws(x, y, exec, ws);
        if self.alpha != 1.0 {
            y.scale(self.alpha);
        }
        if self.beta != 0.0 {
            y.axpy(self.beta, x);
        }
    }

    fn nnz(&self) -> usize {
        self.inner.nnz() + self.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::testing::prop::{all_close, check, forall};
    use crate::util::rng::Rng;

    fn random_sym_csr(rng: &mut Rng, n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            coo.push_sym(i.min(j), i.max(j), rng.normal());
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn csr_and_dense_ops_agree() {
        forall(
            121,
            16,
            |r| {
                let n = 3 + r.below(10);
                (random_sym_csr(r, n), Mat::randn(r, n, 4))
            },
            |(a, x)| {
                let exec = ExecPolicy::serial();
                let dense = DenseOp(a.to_dense());
                all_close(
                    &Operator::apply(a, x, &exec).data,
                    &dense.apply(x, &exec).data,
                    1e-12,
                )
            },
        );
    }

    #[test]
    fn scaled_op_is_affine() {
        forall(
            122,
            16,
            |r| {
                let n = 3 + r.below(8);
                (
                    random_sym_csr(r, n),
                    Mat::randn(r, n, 3),
                    r.uniform(-2.0, 2.0),
                    r.uniform(-2.0, 2.0),
                )
            },
            |(a, x, alpha, beta)| {
                let exec = ExecPolicy::serial();
                let s = ScaledOp::new(a, *alpha, *beta);
                let got = s.apply(x, &exec);
                let mut want = Operator::apply(a, x, &exec);
                want.scale(*alpha);
                want.axpy(*beta, x);
                all_close(&got.data, &want.data, 1e-12)
            },
        );
    }

    #[test]
    fn scaled_identity_coefficients() {
        let a = Csr::eye(5);
        let s = ScaledOp::new(&a, 2.0, -0.5);
        let x = Mat::eye(5);
        let y = s.apply(&x, &ExecPolicy::serial());
        for i in 0..5 {
            assert!((y[(i, i)] - 1.5).abs() < 1e-14);
        }
    }

    #[test]
    fn all_operators_are_thread_count_invariant() {
        forall(
            123,
            10,
            |r| {
                let n = 8 + r.below(40);
                (random_sym_csr(r, n), Mat::randn(r, n, 5))
            },
            |(a, x)| {
                let serial = ExecPolicy::serial();
                let want_csr = Operator::apply(a, x, &serial);
                let dense = DenseOp(a.to_dense());
                let want_dense = dense.apply(x, &serial);
                let scaled = ScaledOp::new(a, -0.7, 0.3);
                let want_scaled = scaled.apply(x, &serial);
                for threads in [2usize, 4] {
                    let exec = ExecPolicy::with_threads(threads);
                    check(
                        Operator::apply(a, x, &exec).data == want_csr.data,
                        format!("csr op differs at {threads} threads"),
                    )?;
                    check(
                        dense.apply(x, &exec).data == want_dense.data,
                        format!("dense op differs at {threads} threads"),
                    )?;
                    check(
                        scaled.apply(x, &exec).data == want_scaled.data,
                        format!("scaled op differs at {threads} threads"),
                    )?;
                }
                Ok(())
            },
        );
    }
}
