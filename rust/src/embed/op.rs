//! The operator abstraction iterated by the recursion.
//!
//! FastEmbed only ever touches the matrix through block products `S·Q`
//! (paper's key structural property), so the driver is generic over
//! [`Operator`]. Implementations here: CSR (the scalable native path),
//! dense (oracles/tests), and an affine wrapper for §3.4 spectrum
//! rescaling. `crate::runtime::PjrtOp` adds the AOT/PJRT tile path.

use crate::linalg::Mat;
use crate::sparse::Csr;

/// A symmetric linear operator usable by the recursion.
pub trait Operator {
    /// Dimension n (operator is n×n).
    fn dim(&self) -> usize;

    /// `y ← S x` for a block `x` (n×d). Must not allocate per call beyond
    /// what the implementation needs internally.
    fn apply_into(&self, x: &Mat, y: &mut Mat);

    /// Convenience allocating form.
    fn apply(&self, x: &Mat) -> Mat {
        let mut y = Mat::zeros(self.dim(), x.cols);
        self.apply_into(x, &mut y);
        y
    }

    /// Number of stored non-zeros (T in the paper's complexity bounds);
    /// used for flop accounting and bench reporting.
    fn nnz(&self) -> usize;
}

impl Operator for Csr {
    fn dim(&self) -> usize {
        assert_eq!(self.rows, self.cols, "operator must be square");
        self.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat) {
        self.spmm_into(x, y);
    }

    fn nnz(&self) -> usize {
        Csr::nnz(self)
    }
}

/// Dense symmetric operator (tests and small oracles).
pub struct DenseOp(pub Mat);

impl Operator for DenseOp {
    fn dim(&self) -> usize {
        assert_eq!(self.0.rows, self.0.cols);
        self.0.rows
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat) {
        let out = self.0.matmul(x);
        y.data.copy_from_slice(&out.data);
    }

    fn nnz(&self) -> usize {
        self.0.rows * self.0.cols
    }
}

/// Affine spectrum rescale `S' = alpha·S + beta·I` (paper §3.4) without
/// materializing a second matrix.
pub struct ScaledOp<'a, O: Operator + ?Sized> {
    pub inner: &'a O,
    pub alpha: f64,
    pub beta: f64,
}

impl<'a, O: Operator + ?Sized> ScaledOp<'a, O> {
    pub fn new(inner: &'a O, alpha: f64, beta: f64) -> Self {
        ScaledOp { inner, alpha, beta }
    }
}

impl<O: Operator + ?Sized> Operator for ScaledOp<'_, O> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn apply_into(&self, x: &Mat, y: &mut Mat) {
        self.inner.apply_into(x, y);
        if self.alpha != 1.0 {
            y.scale(self.alpha);
        }
        if self.beta != 0.0 {
            y.axpy(self.beta, x);
        }
    }

    fn nnz(&self) -> usize {
        self.inner.nnz() + self.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::testing::prop::{all_close, forall};
    use crate::util::rng::Rng;

    fn random_sym_csr(rng: &mut Rng, n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            coo.push_sym(i.min(j), i.max(j), rng.normal());
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn csr_and_dense_ops_agree() {
        forall(
            121,
            16,
            |r| {
                let n = 3 + r.below(10);
                (random_sym_csr(r, n), Mat::randn(r, n, 4))
            },
            |(a, x)| {
                let dense = DenseOp(a.to_dense());
                all_close(&Operator::apply(a, x).data, &dense.apply(x).data, 1e-12)
            },
        );
    }

    #[test]
    fn scaled_op_is_affine() {
        forall(
            122,
            16,
            |r| {
                let n = 3 + r.below(8);
                (
                    random_sym_csr(r, n),
                    Mat::randn(r, n, 3),
                    r.uniform(-2.0, 2.0),
                    r.uniform(-2.0, 2.0),
                )
            },
            |(a, x, alpha, beta)| {
                let s = ScaledOp::new(a, *alpha, *beta);
                let got = s.apply(x);
                let mut want = Operator::apply(a, x);
                want.scale(*alpha);
                want.axpy(*beta, x);
                all_close(&got.data, &want.data, 1e-12)
            },
        );
    }

    #[test]
    fn scaled_identity_coefficients() {
        let a = Csr::eye(5);
        let s = ScaledOp::new(&a, 2.0, -0.5);
        let x = Mat::eye(5);
        let y = s.apply(&x);
        for i in 0..5 {
            assert!((y[(i, i)] - 1.5).abs() < 1e-14);
        }
    }
}
