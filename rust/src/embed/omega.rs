//! JL random projection blocks (paper §3.1).

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// The JL dimension bound of §3.1 / Theorem 1:
/// `d > (4 + 2β) log n / (ε²/2 − ε³/3)` — the smallest integer satisfying
/// it. With β=1, ε=0.5 and n ~ 3·10⁵ this is the "d ≈ 6 log n ≈ 80" the
/// paper quotes.
pub fn jl_dim(n: usize, eps: f64, beta: f64) -> usize {
    assert!(eps > 0.0 && eps < 1.0);
    let denom = eps * eps / 2.0 - eps * eps * eps / 3.0;
    ((4.0 + 2.0 * beta) * (n as f64).ln() / denom).floor() as usize + 1
}

/// n×d Ω with i.i.d. entries uniform on {±1/√d} (Achlioptas [10]).
pub fn rademacher_omega(rng: &mut Rng, n: usize, d: usize) -> Mat {
    let scale = 1.0 / (d as f64).sqrt();
    Mat {
        rows: n,
        cols: d,
        data: (0..n * d).map(|_| rng.rademacher() * scale).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, forall};

    #[test]
    fn jl_dim_matches_paper_scale() {
        // n = 317080 (DBLP), beta = 1, eps = 0.5: the bound lands in the
        // couple-hundred range; the paper's empirical d ~ 6 log n ~ 80
        // undercuts the worst-case constant, as is typical.
        let d = jl_dim(317_080, 0.5, 1.0);
        assert!(d > 400 && d < 1200, "d = {d}");
        // Monotone: smaller eps needs more dimensions.
        assert!(jl_dim(1000, 0.1, 1.0) > jl_dim(1000, 0.5, 1.0));
        assert!(jl_dim(100_000, 0.3, 1.0) > jl_dim(100, 0.3, 1.0));
    }

    #[test]
    fn omega_entries_and_scale() {
        let mut rng = Rng::new(111);
        let d = 16;
        let om = rademacher_omega(&mut rng, 50, d);
        let s = 1.0 / (d as f64).sqrt();
        assert!(om.data.iter().all(|&v| (v - s).abs() < 1e-15 || (v + s).abs() < 1e-15));
        // Column norms are exactly sqrt(n)/sqrt(d).
        for j in 0..d {
            let want = (50.0f64 / d as f64).sqrt();
            assert!((om.col_norm(j) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn omega_preserves_pairwise_distances_statistically() {
        // Empirical JL check: random points in R^n, distances preserved
        // within ±40% for d = 64 (loose sanity, not the tight bound).
        forall(
            112,
            6,
            |r| {
                let n = 60;
                let pts = Mat::randn(r, 8, n);
                let om = rademacher_omega(r, n, 64);
                (pts, om)
            },
            |(pts, om)| {
                let proj = pts.matmul(om);
                for i in 0..pts.rows {
                    for j in 0..i {
                        let orig = pts.row_dist(i, pts, j);
                        let emb = proj.row_dist(i, &proj, j);
                        check(
                            (emb / orig - 1.0).abs() < 0.4,
                            format!("distortion {} at ({i},{j})", emb / orig),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }
}
