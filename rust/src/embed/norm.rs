//! §4 spectral-norm estimation: power iteration on a random block,
//! scaled up by a safety factor — "20 iterates on 6 log n randomly chosen
//! starting vectors, scaled by 1.01".

use super::op::Operator;
use crate::linalg::Mat;
use crate::par::ExecPolicy;
use crate::util::rng::Rng;

/// Parameters of the estimator (paper defaults).
#[derive(Clone, Copy, Debug)]
pub struct NormEstParams {
    pub iters: usize,
    /// Number of starting vectors; `None` → `ceil(6 log n)` capped at n.
    pub vectors: Option<usize>,
    /// Multiplicative safety factor on the (lower-bound) estimate.
    pub safety: f64,
}

impl Default for NormEstParams {
    fn default() -> Self {
        NormEstParams { iters: 20, vectors: None, safety: 1.01 }
    }
}

/// Power-iteration estimate of ‖S‖ = max |λ|. Returns the scaled
/// estimate. The block products run on `exec`'s pool; the estimate is
/// bitwise-identical at any thread count.
pub fn spectral_norm(
    op: &(impl Operator + ?Sized),
    params: &NormEstParams,
    rng: &mut Rng,
    exec: &ExecPolicy,
) -> f64 {
    let n = op.dim();
    if n == 0 {
        return 0.0;
    }
    let b = params
        .vectors
        .unwrap_or_else(|| (6.0 * (n.max(2) as f64).ln()).ceil() as usize)
        .clamp(1, n);
    let mut v = Mat::randn(rng, n, b);
    normalize_cols(&mut v);
    let mut w = Mat::zeros(n, b);
    let mut est = 0.0f64;
    for _ in 0..params.iters {
        op.apply_into(&v, &mut w, exec);
        est = 0.0;
        for j in 0..b {
            let nj = w.col_norm(j);
            est = est.max(nj);
        }
        if est < 1e-300 {
            return 0.0; // zero operator
        }
        std::mem::swap(&mut v, &mut w);
        normalize_cols(&mut v);
    }
    est * params.safety
}

fn normalize_cols(m: &mut Mat) {
    for j in 0..m.cols {
        let n = m.col_norm(j).max(1e-300);
        for i in 0..m.rows {
            m[(i, j)] /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::linalg::eigh::jacobi_eigh;
    use crate::testing::gen::sym_contraction;
    use crate::testing::prop::{check, forall};

    #[test]
    fn estimates_known_diagonal() {
        let mut rng = Rng::new(131);
        let mut m = Mat::zeros(6, 6);
        for (i, &v) in [3.0, -5.0, 1.0, 0.5, -0.2, 4.0].iter().enumerate() {
            m[(i, i)] = v;
        }
        let est =
            spectral_norm(&DenseOp(m), &NormEstParams::default(), &mut rng, &ExecPolicy::serial());
        assert!((est / 5.0 - 1.0).abs() < 0.02, "est {est}");
    }

    #[test]
    fn estimate_brackets_true_norm() {
        forall(
            132,
            8,
            |r| {
                let n = 4 + r.below(10);
                Mat::from_vec(n, n, sym_contraction(r, n))
            },
            |a| {
                let (lam, _) = jacobi_eigh(a);
                let truth = lam.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
                let mut rng = Rng::new(999);
                let est = spectral_norm(
                    &DenseOp(a.clone()),
                    &NormEstParams { iters: 50, ..Default::default() },
                    &mut rng,
                    &ExecPolicy::serial(),
                );
                // Power iteration lower-bounds; x1.01 typically crosses.
                check(est >= truth * 0.85, format!("est {est} << truth {truth}"))?;
                check(est <= truth * 1.02 + 1e-12, format!("est {est} >> truth {truth}"))
            },
        );
    }

    #[test]
    fn zero_operator() {
        let mut rng = Rng::new(133);
        let est = spectral_norm(
            &DenseOp(Mat::zeros(5, 5)),
            &NormEstParams::default(),
            &mut rng,
            &ExecPolicy::serial(),
        );
        assert_eq!(est, 0.0);
    }
}
