//! The paper's algorithm: compressive spectral embedding (FastEmbed).
//!
//! * [`omega`] — JL random-projection blocks Ω (±1/√d entries) and the
//!   JL dimension bound of §3.1.
//! * [`op`] — the [`op::Operator`] abstraction the recursion iterates:
//!   native CSR, dense, affine-rescaled wrappers; the PJRT tile operator
//!   lives in `crate::runtime` and plugs in through the same trait.
//! * [`norm`] — §4 spectral-norm estimation (power iteration).
//! * [`fastembed`] — Algorithm 1 + §3.5 general-matrix embedding + §4
//!   cascading, over any operator.
//! * [`density`] — KPM eigenvalue counting / spectral density with the
//!   same recursion (refs [25][26]); SVD-free threshold selection.

pub mod density;
pub mod fastembed;
pub mod norm;
pub mod omega;
pub mod op;

pub use fastembed::{Embedding, FastEmbed, GeneralEmbedding, Params};
