//! Spectral density / eigenvalue counting — the §2-adjacent application
//! of the same machinery (kernel polynomial method, refs [25][26] of the
//! paper): estimate how many eigenvalues of S fall in a band [a, b]
//! without any eigendecomposition.
//!
//! count(a, b) = tr I_{[a,b]}(S) ≈ (n/m)·Σ_j ωⱼᵀ f̃_L(S) ωⱼ / ‖ωⱼ‖² —
//! a Hutchinson trace estimator over the same Rademacher vectors and the
//! same three-term recursion FastEmbed already runs. This is how the
//! library picks the step threshold c "capture the top k eigenvectors"
//! without the Lanczos probe (see [`count_above`] / [`threshold_for_count`]).

use super::fastembed::apply_series;
use super::op::Operator;
use crate::linalg::Mat;
use crate::par::ExecPolicy;
use crate::poly::{chebyshev, legendre, Basis, Series};
use crate::util::rng::Rng;

/// Parameters for the KPM eigenvalue counter.
#[derive(Clone, Copy, Debug)]
pub struct DensityParams {
    /// Polynomial order of the band-indicator approximation.
    pub order: usize,
    /// Number of Hutchinson probe vectors.
    pub probes: usize,
    /// Basis (Chebyshev + Jackson damping is the classic KPM choice).
    pub basis: Basis,
    /// Apply Jackson damping (Chebyshev only) to suppress Gibbs ringing.
    pub jackson: bool,
    /// Threading for the probe block products (deterministic).
    pub exec: ExecPolicy,
}

impl Default for DensityParams {
    fn default() -> Self {
        DensityParams {
            order: 120,
            probes: 16,
            basis: Basis::Chebyshev,
            jackson: true,
            exec: ExecPolicy::serial(),
        }
    }
}

fn band_series(a: f64, b: f64, p: &DensityParams) -> Series {
    match p.basis {
        Basis::Legendre => legendre::indicator_coeffs(p.order, a, b),
        Basis::Chebyshev => {
            // I(a <= x <= b) = I(x >= a) - I(x > b).
            let lo = chebyshev::step_coeffs(p.order, a);
            let hi = chebyshev::step_coeffs(p.order, b);
            let mut s = Series {
                basis: Basis::Chebyshev,
                coeffs: lo.coeffs.iter().zip(&hi.coeffs).map(|(l, h)| l - h).collect(),
            };
            // I(x >= b) excludes b itself from [a, b]; add it back only in
            // the limit sense — for counting purposes the measure-zero
            // endpoint is immaterial.
            if p.jackson {
                s = chebyshev::damped(&s, &chebyshev::jackson_damping(p.order));
            }
            s
        }
    }
}

/// Estimated number of eigenvalues of `op` (with ‖S‖ ≤ 1) in `[a, b]`.
pub fn count_in_band(
    op: &(impl Operator + ?Sized),
    a: f64,
    b: f64,
    params: &DensityParams,
    rng: &mut Rng,
) -> f64 {
    assert!(b >= a, "empty band");
    let n = op.dim();
    let m = params.probes.max(1);
    let series = band_series(a.clamp(-1.0, 1.0), b.clamp(-1.0, 1.0), params);
    // Probe block: Rademacher entries, E[ω ωᵀ] = I.
    let mut omega = Mat::zeros(n, m);
    for v in omega.data.iter_mut() {
        *v = rng.rademacher();
    }
    let mut mv = 0;
    let fo = apply_series(op, &series, &omega, &mut mv, &params.exec);
    // tr f(S) ≈ (1/m) Σ_j ωⱼᵀ f(S) ωⱼ / (ωⱼᵀωⱼ/n) ; ωⱼᵀωⱼ = n exactly.
    let mut acc = 0.0;
    for j in 0..m {
        let mut dot = 0.0;
        for i in 0..n {
            dot += omega[(i, j)] * fo[(i, j)];
        }
        acc += dot;
    }
    acc / m as f64
}

/// Estimated number of eigenvalues ≥ `c`.
pub fn count_above(
    op: &(impl Operator + ?Sized),
    c: f64,
    params: &DensityParams,
    rng: &mut Rng,
) -> f64 {
    count_in_band(op, c, 1.0, params, rng)
}

/// Find a threshold `c` such that ≈ `k` eigenvalues lie above it, by
/// bisection on the KPM counter — the SVD-free way to set the paper's
/// f = I(λ ≥ λ_k) weighing function ("an elegant approach for implicitly
/// optimizing over k", §5).
pub fn threshold_for_count(
    op: &(impl Operator + ?Sized),
    k: usize,
    params: &DensityParams,
    rng: &mut Rng,
) -> f64 {
    let (mut lo, mut hi) = (-1.0f64, 1.0f64); // count(hi)=0 <= k <= count(lo)=n
    for _ in 0..24 {
        let mid = (lo + hi) / 2.0;
        let cnt = count_above(op, mid, params, rng);
        if cnt > k as f64 {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    (lo + hi) / 2.0
}

/// Full spectral-density histogram: eigenvalue counts over `bins` uniform
/// bands of [-1, 1] (the [25][26] use case).
pub fn spectral_histogram(
    op: &(impl Operator + ?Sized),
    bins: usize,
    params: &DensityParams,
    rng: &mut Rng,
) -> Vec<f64> {
    (0..bins)
        .map(|t| {
            let a = -1.0 + 2.0 * t as f64 / bins as f64;
            let b = -1.0 + 2.0 * (t + 1) as f64 / bins as f64;
            count_in_band(op, a, b, params, rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::op::DenseOp;
    use crate::sparse::{gen, graph};

    fn diag_op(vals: &[f64]) -> DenseOp {
        let n = vals.len();
        let mut m = Mat::zeros(n, n);
        for (i, &v) in vals.iter().enumerate() {
            m[(i, i)] = v;
        }
        DenseOp(m)
    }

    #[test]
    fn counts_known_diagonal_spectrum() {
        // 10 eigenvalues at 0.9, 30 at 0.1, 20 at -0.5.
        let mut vals = vec![0.9; 10];
        vals.extend(vec![0.1; 30]);
        vals.extend(vec![-0.5; 20]);
        let op = diag_op(&vals);
        let mut rng = Rng::new(71);
        let p = DensityParams { probes: 32, ..Default::default() };
        let hi = count_in_band(&op, 0.5, 1.0, &p, &mut rng);
        let mid = count_in_band(&op, -0.1, 0.3, &p, &mut rng);
        let lo = count_in_band(&op, -0.7, -0.3, &p, &mut rng);
        assert!((hi - 10.0).abs() < 2.5, "hi band {hi}");
        assert!((mid - 30.0).abs() < 5.0, "mid band {mid}");
        assert!((lo - 20.0).abs() < 4.0, "lo band {lo}");
    }

    #[test]
    fn histogram_sums_to_n() {
        let mut rng = Rng::new(72);
        let g = gen::erdos_renyi(&mut rng, 300, 900);
        let na = graph::normalized_adjacency(&g.adj);
        let p = DensityParams { probes: 24, ..Default::default() };
        let hist = spectral_histogram(&na, 8, &p, &mut rng);
        let total: f64 = hist.iter().sum();
        assert!((total - 300.0).abs() < 20.0, "histogram total {total}");
    }

    #[test]
    fn count_above_finds_community_cluster() {
        let mut rng = Rng::new(73);
        let g = gen::sbm_by_degree(&mut rng, 800, 8, 12.0, 0.8);
        let na = graph::normalized_adjacency(&g.adj);
        let p = DensityParams { probes: 24, ..Default::default() };
        // 8 community eigenvalues near 0.9, bulk below ~0.6.
        let cnt = count_above(&na, 0.75, &p, &mut rng);
        assert!((cnt - 8.0).abs() < 2.5, "community count {cnt}");
    }

    #[test]
    fn threshold_for_count_brackets_lambda_k() {
        let mut vals: Vec<f64> = (0..50).map(|i| 0.95 - 0.015 * i as f64).collect();
        vals.extend(vec![-0.2; 50]);
        let op = diag_op(&vals);
        let mut rng = Rng::new(74);
        let p = DensityParams { probes: 32, order: 160, ..Default::default() };
        let c = threshold_for_count(&op, 20, &p, &mut rng);
        // lambda_20 = 0.95 - 0.015*19 = 0.665; lambda_21 = 0.65.
        assert!(c > 0.55 && c < 0.75, "threshold {c}");
    }

    #[test]
    fn legendre_basis_also_works() {
        let op = diag_op(&[0.8, 0.8, -0.3, -0.3, -0.3, 0.0]);
        let mut rng = Rng::new(75);
        let p = DensityParams {
            basis: Basis::Legendre,
            jackson: false,
            probes: 48,
            order: 100,
            ..Default::default()
        };
        let cnt = count_in_band(&op, 0.6, 1.0, &p, &mut rng);
        assert!((cnt - 2.0).abs() < 1.0, "legendre count {cnt}");
    }
}
