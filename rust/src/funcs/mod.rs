//! Spectral weighing functions f(λ) and spectrum rescaling.
//!
//! The embedding is `E = [f(λ₁)v₁ … f(λₙ)vₙ]`; the paper's examples:
//! * `f(x) = x`                      — PCA / plain spectral projection,
//! * `f(x) = I(x ≥ c)`               — rank-selection used for graph cuts
//!                                     and in both paper experiments,
//! * `f(x) = 1`                      — unit weighting on a band,
//! * `f(x) = 1/sqrt(1-x)`            — commute-time embedding,
//! * `f(x) = I(x ≥ c)/sqrt(1-x)`     — commute time with small-eigenvector
//!                                     suppression (§2's flexibility note).

use crate::poly::cascade::nth_root_nonneg;

/// A spectral weighing function over λ ∈ [-1, 1].
#[derive(Clone, Debug)]
pub enum SpectralFn {
    /// f(x) = I(x ≥ c) — keep the eigenspace above threshold `c`.
    Step { c: f64 },
    /// f(x) = I(a ≤ x ≤ b) — band indicator (eigenvalue-count estimation,
    /// [25][26]-style filters).
    Band { a: f64, b: f64 },
    /// f(x) = x — PCA weighting.
    Pca,
    /// f(x) = |x| — PCA magnitude weighting (sign-free, §3.5 dilations).
    AbsPca,
    /// f(x) = I(x ≥ c) / sqrt(1 - x), clamped at `1 - eps` — regularized
    /// commute-time embedding with small-eigenvector suppression.
    CommuteTime { c: f64, eps: f64 },
    /// f(x) = exp(t (x - 1)) — diffusion/heat-kernel embedding at time t.
    Diffusion { t: f64 },
}

impl SpectralFn {
    /// Point evaluation.
    pub fn eval(&self, x: f64) -> f64 {
        match *self {
            SpectralFn::Step { c } => {
                if x >= c {
                    1.0
                } else {
                    0.0
                }
            }
            SpectralFn::Band { a, b } => {
                if x >= a && x <= b {
                    1.0
                } else {
                    0.0
                }
            }
            SpectralFn::Pca => x,
            SpectralFn::AbsPca => x.abs(),
            SpectralFn::CommuteTime { c, eps } => {
                if x >= c {
                    1.0 / (1.0 - x).max(eps).sqrt()
                } else {
                    0.0
                }
            }
            SpectralFn::Diffusion { t } => (t * (x - 1.0)).exp(),
        }
    }

    /// The cascade stage function g with g^b = f (paper §4): evaluate
    /// f^{1/b}. All our f are non-negative, so the real root is safe.
    pub fn eval_root(&self, x: f64, b: usize) -> f64 {
        nth_root_nonneg(self.eval(x).max(0.0), b)
    }

    /// Whether f is a {0,1} indicator (closed-form Legendre coefficients
    /// are available and cascading is exact: f^{1/b} = f).
    pub fn is_indicator(&self) -> bool {
        matches!(self, SpectralFn::Step { .. } | SpectralFn::Band { .. })
    }

    /// The odd extension used to embed general matrices through the
    /// dilation S = [[0, Aᵀ],[A, 0]] (paper §3.5):
    /// f'(x) = f(x) I(x ≥ 0) − f(−x) I(x < 0).
    pub fn dilated(&self) -> DilatedFn<'_> {
        DilatedFn { inner: self }
    }
}

/// View of a [`SpectralFn`] through the §3.5 odd extension.
pub struct DilatedFn<'a> {
    inner: &'a SpectralFn,
}

impl DilatedFn<'_> {
    pub fn eval(&self, x: f64) -> f64 {
        if x >= 0.0 {
            self.inner.eval(x)
        } else {
            -self.inner.eval(-x)
        }
    }
}

/// Affine spectrum rescaling (paper §3.4): given bounds
/// `sigma_min <= λ <= sigma_max`, maps the operator `S` to
/// `S' = 2S/(σmax−σmin) − (σmax+σmin)/(σmax−σmin) I` with spectrum in
/// [-1, 1], and transports f accordingly.
#[derive(Clone, Copy, Debug)]
pub struct Rescale {
    pub sigma_min: f64,
    pub sigma_max: f64,
}

impl Rescale {
    pub fn new(sigma_min: f64, sigma_max: f64) -> Self {
        assert!(sigma_max > sigma_min, "need sigma_max > sigma_min");
        Rescale { sigma_min, sigma_max }
    }

    /// Identity rescale for operators already in [-1, 1].
    pub fn unit() -> Self {
        Rescale { sigma_min: -1.0, sigma_max: 1.0 }
    }

    /// Coefficients (alpha, beta) of S' = alpha S + beta I.
    pub fn operator_coeffs(&self) -> (f64, f64) {
        let span = self.sigma_max - self.sigma_min;
        (2.0 / span, -(self.sigma_max + self.sigma_min) / span)
    }

    /// Map a rescaled eigenvalue x ∈ [-1,1] back to the original λ.
    pub fn to_original(&self, x: f64) -> f64 {
        let span = self.sigma_max - self.sigma_min;
        x * span / 2.0 + (self.sigma_max + self.sigma_min) / 2.0
    }

    /// Map an original eigenvalue λ to the rescaled x.
    pub fn to_unit(&self, lam: f64) -> f64 {
        let (a, b) = self.operator_coeffs();
        a * lam + b
    }

    /// Transport f: f'(x) = f(λ(x)).
    pub fn transport<'a>(&'a self, f: &'a SpectralFn) -> impl Fn(f64) -> f64 + 'a {
        move |x| f.eval(self.to_original(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, close, forall};

    #[test]
    fn step_and_band() {
        let f = SpectralFn::Step { c: 0.5 };
        assert_eq!(f.eval(0.6), 1.0);
        assert_eq!(f.eval(0.5), 1.0);
        assert_eq!(f.eval(0.49), 0.0);
        let g = SpectralFn::Band { a: -0.2, b: 0.2 };
        assert_eq!(g.eval(0.0), 1.0);
        assert_eq!(g.eval(0.3), 0.0);
        assert!(f.is_indicator() && g.is_indicator());
        assert!(!SpectralFn::Pca.is_indicator());
    }

    #[test]
    fn commute_time_regularized() {
        let f = SpectralFn::CommuteTime { c: 0.0, eps: 0.01 };
        assert!((f.eval(0.0) - 1.0).abs() < 1e-12);
        // Clamped near 1:
        assert!((f.eval(0.9999) - 10.0).abs() < 1e-9);
        assert_eq!(f.eval(-0.5), 0.0);
    }

    #[test]
    fn root_recomposes() {
        forall(
            71,
            64,
            |r| (r.uniform(-1.0, 1.0), 1 + r.below(4)),
            |&(x, b)| {
                let f = SpectralFn::Diffusion { t: 2.0 };
                let root = f.eval_root(x, b);
                close(root.powi(b as i32), f.eval(x), 1e-10)
            },
        );
    }

    #[test]
    fn indicator_root_is_itself() {
        let f = SpectralFn::Step { c: 0.3 };
        for &x in &[-0.5, 0.2, 0.31, 0.9] {
            assert_eq!(f.eval_root(x, 3), f.eval(x));
        }
    }

    #[test]
    fn dilated_is_odd_extension() {
        let f = SpectralFn::Step { c: 0.5 };
        let d = f.dilated();
        assert_eq!(d.eval(0.7), 1.0);
        assert_eq!(d.eval(-0.7), -1.0);
        assert_eq!(d.eval(0.2), 0.0);
        assert_eq!(d.eval(-0.2), 0.0);
    }

    #[test]
    fn rescale_roundtrip() {
        forall(
            72,
            64,
            |r| {
                let lo = r.uniform(-5.0, 0.0);
                let hi = lo + r.uniform(0.5, 10.0);
                (lo, hi, r.uniform(lo, hi))
            },
            |&(lo, hi, lam)| {
                let rs = Rescale::new(lo, hi);
                let x = rs.to_unit(lam);
                check(x >= -1.0 - 1e-9 && x <= 1.0 + 1e-9, format!("x={x} outside"))?;
                close(rs.to_original(x), lam, 1e-10)
            },
        );
    }

    #[test]
    fn rescale_operator_coeffs_map_endpoints() {
        let rs = Rescale::new(2.0, 6.0);
        let (a, b) = rs.operator_coeffs();
        assert!((a * 2.0 + b + 1.0).abs() < 1e-12); // sigma_min -> -1
        assert!((a * 6.0 + b - 1.0).abs() < 1e-12); // sigma_max -> +1
    }

    #[test]
    fn transport_matches_composition() {
        let rs = Rescale::new(0.0, 4.0);
        let f = SpectralFn::Step { c: 3.0 };
        let ft = rs.transport(&f);
        assert_eq!(ft(rs.to_unit(3.5)), 1.0);
        assert_eq!(ft(rs.to_unit(2.9)), 0.0);
    }
}
