//! Power-iteration clustering (Lin & Cohen [18]) — the §2 "sidesteps the
//! SVD" prior art the paper generalizes: run the random-walk operator on
//! a few random vectors, stop *before* convergence, cluster the iterates.
//! Implemented as a baseline to compare against FastEmbed's controlled
//! embedding (PIC offers no control over the effective weighing function;
//! FastEmbed's f(λ) is explicit).

use super::kmeans::{kmeans, KmeansParams, KmeansResult};
use crate::embed::op::Operator;
use crate::linalg::Mat;
use crate::par::ExecPolicy;
use crate::util::rng::Rng;

/// Parameters for [`pic`].
#[derive(Clone, Copy, Debug)]
pub struct PicParams {
    /// Number of independent power-iteration embeddings (PIC's "d").
    pub vectors: usize,
    /// Power iterations (stopped early by design).
    pub iters: usize,
    pub kmeans: KmeansParams,
    /// Threading for the power-iteration block products.
    pub exec: ExecPolicy,
}

impl Default for PicParams {
    fn default() -> Self {
        PicParams {
            vectors: 4,
            iters: 30,
            kmeans: KmeansParams::default(),
            exec: ExecPolicy::serial(),
        }
    }
}

/// Run PIC on a (random-walk) operator: early-stopped power iteration on
/// `vectors` random starts, then K-means on the resulting n×vectors
/// embedding. Returns (clustering, embedding).
pub fn pic(op: &(impl Operator + ?Sized), params: &PicParams, rng: &mut Rng) -> (KmeansResult, Mat) {
    let n = op.dim();
    let d = params.vectors.max(1);
    let mut v = Mat::zeros(n, d);
    for x in v.data.iter_mut() {
        *x = rng.f64();
    }
    normalize_cols(&mut v);
    let mut w = Mat::zeros(n, d);
    for _ in 0..params.iters {
        op.apply_into(&v, &mut w, &params.exec);
        std::mem::swap(&mut v, &mut w);
        normalize_cols(&mut v);
    }
    // PIC clusters the (scaled) iterate entries; scale rows to unit max
    // per column for numerical comparability across columns.
    let km = kmeans(&v, &params.kmeans, rng);
    (km, v)
}

fn normalize_cols(m: &mut Mat) {
    for j in 0..m.cols {
        let norm = m.col_norm(j).max(1e-300);
        for i in 0..m.rows {
            m[(i, j)] /= norm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::metrics::nmi;
    use crate::sparse::{gen, graph};

    #[test]
    fn pic_recovers_strong_communities() {
        let mut rng = Rng::new(81);
        let g = gen::sbm_by_degree(&mut rng, 600, 4, 14.0, 0.4);
        let labels = g.labels.clone().unwrap();
        let rw = graph::random_walk_matrix(&g.adj);
        let params = PicParams {
            vectors: 6,
            iters: 25,
            kmeans: KmeansParams { k: 4, ..Default::default() },
            ..Default::default()
        };
        let (km, emb) = pic(&rw, &params, &mut rng);
        assert_eq!(emb.rows, 600);
        let score = nmi(&km.assignment, &labels);
        assert!(score > 0.6, "PIC NMI {score}");
    }

    #[test]
    fn too_many_iterations_converge_to_stationary() {
        // The "stop prior to convergence" point: with huge iteration
        // counts the iterates collapse toward the dominant eigenvector
        // and the embedding loses discriminative power.
        let mut rng = Rng::new(82);
        let g = gen::sbm_by_degree(&mut rng, 400, 4, 14.0, 0.4);
        let labels = g.labels.clone().unwrap();
        let rw = graph::random_walk_matrix(&g.adj);
        let run = |iters: usize, seed: u64| -> f64 {
            let mut r = Rng::new(seed);
            let params = PicParams {
                vectors: 4,
                iters,
                kmeans: KmeansParams { k: 4, ..Default::default() },
                ..Default::default()
            };
            let (km, _) = pic(&rw, &params, &mut r);
            nmi(&km.assignment, &labels)
        };
        // Early-stopped beats (or at least matches) heavily converged.
        let early: f64 = (0..3).map(|s| run(20, s)).sum::<f64>() / 3.0;
        let late: f64 = (0..3).map(|s| run(4000, s)).sum::<f64>() / 3.0;
        assert!(
            early >= late - 0.05,
            "early {early} should not lose to converged {late}"
        );
    }
}
