//! Clustering quality metrics: Newman modularity [28] (the paper's
//! reported score) and NMI against planted ground truth (ours, since the
//! SBM substitution gives us true labels).

use crate::sparse::Csr;

/// Newman modularity of a hard partition on an undirected graph:
/// `Q = Σ_c [ e_c / m − (deg_c / 2m)² ]`, Q ∈ [−1/2, 1).
pub fn modularity(adj: &Csr, assignment: &[usize]) -> f64 {
    assert_eq!(adj.rows, assignment.len());
    let two_m: f64 = adj.values.iter().sum(); // = 2m for symmetric adjacency
    if two_m <= 0.0 {
        return 0.0;
    }
    let k = assignment.iter().copied().max().map_or(0, |m| m + 1);
    let mut internal = vec![0.0f64; k]; // 2 * within-community edge weight
    let mut degree = vec![0.0f64; k];
    for i in 0..adj.rows {
        let (idx, val) = adj.row(i);
        let ci = assignment[i];
        for (&j, &v) in idx.iter().zip(val) {
            degree[ci] += v;
            if assignment[j as usize] == ci {
                internal[ci] += v;
            }
        }
    }
    (0..k)
        .map(|c| internal[c] / two_m - (degree[c] / two_m) * (degree[c] / two_m))
        .sum()
}

/// Normalized mutual information between two hard partitions (0..=1).
pub fn nmi(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 1.0;
    }
    let ka = a.iter().copied().max().unwrap_or(0) + 1;
    let kb = b.iter().copied().max().unwrap_or(0) + 1;
    let mut joint = vec![0.0f64; ka * kb];
    let mut pa = vec![0.0f64; ka];
    let mut pb = vec![0.0f64; kb];
    let inv = 1.0 / n as f64;
    for (&x, &y) in a.iter().zip(b) {
        joint[x * kb + y] += inv;
        pa[x] += inv;
        pb[y] += inv;
    }
    let mut mi = 0.0;
    for x in 0..ka {
        for y in 0..kb {
            let p = joint[x * kb + y];
            if p > 0.0 {
                mi += p * (p / (pa[x] * pb[y])).ln();
            }
        }
    }
    let ent = |p: &[f64]| -> f64 { -p.iter().filter(|&&v| v > 0.0).map(|&v| v * v.ln()).sum::<f64>() };
    let (ha, hb) = (ent(&pa), ent(&pb));
    if ha <= 0.0 && hb <= 0.0 {
        return 1.0; // both partitions trivial and identical in structure
    }
    let denom = (ha * hb).sqrt();
    if denom <= 0.0 {
        0.0
    } else {
        (mi / denom).clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen::sbm;
    use crate::util::rng::Rng;

    fn two_cliques() -> Csr {
        // Two 4-cliques joined by one edge.
        let mut edges = Vec::new();
        for block in 0..2 {
            let off = block * 4;
            for i in 0..4 {
                for j in 0..i {
                    edges.push((off + j, off + i));
                }
            }
        }
        edges.push((3, 4));
        Csr::from_coo(&Coo::from_undirected_edges(8, &edges))
    }

    #[test]
    fn modularity_of_planted_partition_is_high() {
        let adj = two_cliques();
        let good = [0, 0, 0, 0, 1, 1, 1, 1];
        let q = modularity(&adj, &good);
        assert!(q > 0.4, "good partition q = {q}");
        // Random-ish partition scores lower.
        let bad = [0, 1, 0, 1, 0, 1, 0, 1];
        assert!(modularity(&adj, &bad) < q);
    }

    #[test]
    fn modularity_single_community_is_zero() {
        let adj = two_cliques();
        let q = modularity(&adj, &[0; 8]);
        assert!(q.abs() < 1e-12);
    }

    #[test]
    fn modularity_bounds() {
        let mut rng = Rng::new(201);
        let g = sbm(&mut rng, 200, 4, 0.2, 0.01);
        let labels = g.labels.unwrap();
        let q = modularity(&g.adj, &labels);
        assert!(q > -0.5 && q < 1.0);
        assert!(q > 0.5, "planted SBM labels give q = {q}");
    }

    #[test]
    fn nmi_identity_and_permutation_invariance() {
        let a = [0, 0, 1, 1, 2, 2];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        let b = [2, 2, 0, 0, 1, 1]; // relabeled
        assert!((nmi(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_independent_partitions_low() {
        let mut rng = Rng::new(202);
        let n = 4000;
        let a: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let b: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        assert!(nmi(&a, &b) < 0.02);
    }

    #[test]
    fn nmi_degenerate_cases() {
        assert!((nmi(&[], &[]) - 1.0).abs() < 1e-12);
        assert!((nmi(&[0, 0, 0], &[0, 0, 0]) - 1.0).abs() < 1e-12);
        // One trivial, one informative: NMI 0 (denominator guard).
        assert_eq!(nmi(&[0, 0, 0, 0], &[0, 1, 2, 3]), 0.0);
    }
}
