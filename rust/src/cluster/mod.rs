//! Downstream inference: K-means clustering on embedding rows and the
//! graph-quality metrics the paper reports.

pub mod kmeans;
pub mod metrics;
pub mod pic;

pub use kmeans::{kmeans, KmeansParams, KmeansResult};
pub use metrics::{modularity, nmi};
