//! K-means (k-means++ seeding + Lloyd) over embedding rows — the paper's
//! downstream task for the Amazon experiment (K = 200, 25 restarts,
//! median modularity reported).

use crate::linalg::Mat;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct KmeansParams {
    pub k: usize,
    pub max_iters: usize,
    /// Relative cost-improvement threshold for early stop.
    pub tol: f64,
}

impl Default for KmeansParams {
    fn default() -> Self {
        KmeansParams { k: 8, max_iters: 50, tol: 1e-6 }
    }
}

pub struct KmeansResult {
    pub assignment: Vec<usize>,
    pub centroids: Mat,
    /// Final within-cluster sum of squares.
    pub cost: f64,
    pub iters: usize,
}

/// Lloyd's algorithm with k-means++ initialization on the rows of `x`.
pub fn kmeans(x: &Mat, params: &KmeansParams, rng: &mut Rng) -> KmeansResult {
    let (n, dim) = (x.rows, x.cols);
    let k = params.k.min(n).max(1);
    let mut centroids = kmeanspp_init(x, k, rng);
    let mut assignment = vec![0usize; n];
    let mut prev_cost = f64::INFINITY;
    let mut iters = 0;

    for it in 0..params.max_iters {
        iters = it + 1;
        // Assign.
        let mut cost = 0.0;
        for i in 0..n {
            let (best, d2) = nearest(x.row(i), &centroids);
            assignment[i] = best;
            cost += d2;
        }
        // Update.
        let mut counts = vec![0usize; k];
        let mut sums = Mat::zeros(k, dim);
        for i in 0..n {
            let c = assignment[i];
            counts[c] += 1;
            for (s, v) in sums.row_mut(c).iter_mut().zip(x.row(i)) {
                *s += v;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Empty cluster: reseed at the point farthest from its
                // centroid (standard fix).
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = dist2(x.row(a), centroids.row(assignment[a]));
                        let db = dist2(x.row(b), centroids.row(assignment[b]));
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(x.row(far));
            } else {
                let inv = 1.0 / counts[c] as f64;
                for (cv, sv) in centroids.row_mut(c).iter_mut().zip(sums.row(c)) {
                    *cv = sv * inv;
                }
            }
        }
        if (prev_cost - cost).abs() <= params.tol * prev_cost.max(1e-300) {
            break;
        }
        prev_cost = cost;
    }
    // Final assignment/cost against the last centroids.
    let mut cost = 0.0;
    for i in 0..n {
        let (best, d2) = nearest(x.row(i), &centroids);
        assignment[i] = best;
        cost += d2;
    }
    KmeansResult { assignment, centroids, cost, iters }
}

fn kmeanspp_init(x: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = x.rows;
    let mut centroids = Mat::zeros(k, x.cols);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut d2 = vec![0.0f64; n];
    for i in 0..n {
        d2[i] = dist2(x.row(i), centroids.row(0));
    }
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            // Sample proportional to squared distance.
            let mut target = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    chosen = i;
                    break;
                }
                target -= w;
            }
            chosen
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
        for i in 0..n {
            d2[i] = d2[i].min(dist2(x.row(i), centroids.row(c)));
        }
    }
    centroids
}

#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn nearest(row: &[f64], centroids: &Mat) -> (usize, f64) {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for c in 0..centroids.rows {
        let d = dist2(row, centroids.row(c));
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::gaussian_mixture;
    use crate::testing::prop::{check, forall};

    #[test]
    fn recovers_separated_gaussian_clusters() {
        let mut rng = Rng::new(191);
        let (pts, labels) = gaussian_mixture(&mut rng, 300, 4, 3, 12.0);
        let x = Mat::from_vec(300, 4, pts);
        let res = kmeans(&x, &KmeansParams { k: 3, ..Default::default() }, &mut rng);
        // Clustering should agree with ground truth up to permutation:
        // check pairs.
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..300 {
            for j in 0..i {
                total += 1;
                let same_true = labels[i] == labels[j];
                let same_got = res.assignment[i] == res.assignment[j];
                if same_true == same_got {
                    agree += 1;
                }
            }
        }
        let rate = agree as f64 / total as f64;
        assert!(rate > 0.97, "pair agreement {rate}");
    }

    #[test]
    fn cost_decreases_with_more_clusters() {
        forall(
            192,
            6,
            |r| Mat::randn(r, 80, 3),
            |x| {
                let mut r1 = Rng::new(5);
                let c2 = kmeans(x, &KmeansParams { k: 2, ..Default::default() }, &mut r1).cost;
                let mut r2 = Rng::new(5);
                let c8 = kmeans(x, &KmeansParams { k: 8, ..Default::default() }, &mut r2).cost;
                check(c8 <= c2 + 1e-9, format!("k=8 cost {c8} > k=2 cost {c2}"))
            },
        );
    }

    #[test]
    fn k_one_gives_total_variance() {
        let mut rng = Rng::new(193);
        let x = Mat::randn(&mut rng, 50, 2);
        let res = kmeans(&x, &KmeansParams { k: 1, ..Default::default() }, &mut rng);
        // Centroid = mean; cost = sum of squared deviations.
        let mut mean = vec![0.0; 2];
        for i in 0..50 {
            for (m, v) in mean.iter_mut().zip(x.row(i)) {
                *m += v / 50.0;
            }
        }
        let want: f64 = (0..50).map(|i| dist2(x.row(i), &mean)).sum();
        assert!((res.cost - want).abs() < 1e-9);
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::new(194);
        let x = Mat::randn(&mut rng, 5, 2);
        let res = kmeans(&x, &KmeansParams { k: 50, ..Default::default() }, &mut rng);
        assert!(res.cost < 1e-18, "each point its own cluster, cost {}", res.cost);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let x = Mat::randn(&mut Rng::new(1), 60, 3);
        let a = kmeans(&x, &KmeansParams { k: 4, ..Default::default() }, &mut r1);
        let b = kmeans(&x, &KmeansParams { k: 4, ..Default::default() }, &mut r2);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.cost, b.cost);
    }
}
