//! K-means (k-means++ seeding + Lloyd) over embedding rows — the paper's
//! downstream task for the Amazon experiment (K = 200, 25 restarts,
//! median modularity reported).

use crate::linalg::Mat;
use crate::par::{self, ExecPolicy};
use crate::util::rng::Rng;

/// Rows per chunk of the parallel assignment and update steps. Fixed
/// (not derived from the thread count) so the chunk-folded cost and
/// centroid-sum reductions — and with them the early-stop iteration
/// count — are identical at any thread count.
const ASSIGN_ROWS_PER_CHUNK: usize = 1024;

/// Cap on the update step's parallel stripes. Each stripe carries a full
/// k×(dim+1) accumulator, so unlike the assignment chunking (which has
/// no per-chunk state) the update scratch must stay bounded: at most
/// `UPDATE_STRIPES × k × (dim+1)` doubles whatever n is. A constant (not
/// thread-derived) so the merge structure — and every output bit — is
/// identical at any thread count.
const UPDATE_STRIPES: usize = 32;

#[derive(Clone, Copy, Debug)]
pub struct KmeansParams {
    pub k: usize,
    pub max_iters: usize,
    /// Relative cost-improvement threshold for early stop.
    pub tol: f64,
    /// Threading for the assignment step (the dominant n·k·d cost) and
    /// the centroid update (per-chunk partial sums merged in fixed chunk
    /// order). Assignments, cost, and centroids are
    /// thread-count-independent.
    pub exec: ExecPolicy,
}

impl Default for KmeansParams {
    fn default() -> Self {
        KmeansParams { k: 8, max_iters: 50, tol: 1e-6, exec: ExecPolicy::serial() }
    }
}

pub struct KmeansResult {
    pub assignment: Vec<usize>,
    pub centroids: Mat,
    /// Final within-cluster sum of squares.
    pub cost: f64,
    pub iters: usize,
}

/// Lloyd's algorithm with k-means++ initialization on the rows of `x`.
pub fn kmeans(x: &Mat, params: &KmeansParams, rng: &mut Rng) -> KmeansResult {
    let (n, dim) = (x.rows, x.cols);
    let k = params.k.min(n).max(1);
    let mut centroids = kmeanspp_init(x, k, rng);
    let mut assignment = vec![0usize; n];
    let mut prev_cost = f64::INFINITY;
    let mut iters = 0;

    // Update-step scratch, allocated once: per-stripe (sums | counts)
    // accumulators laid out as one flat buffer so the parallel region
    // writes disjoint stripes, plus the merged sums/counts. Counts ride
    // along as f64 (exact below 2^53).
    let nchunks = par::fixed_chunks(n.max(1), ASSIGN_ROWS_PER_CHUNK).min(UPDATE_STRIPES);
    let row_ranges = par::even_ranges(n, nchunks);
    let stripe_ranges: Vec<std::ops::Range<usize>> =
        (0..row_ranges.len()).map(|c| c..c + 1).collect();
    let stride = k * dim + k;
    let mut partials = vec![0.0f64; row_ranges.len() * stride];
    let mut counts = vec![0usize; k];
    let mut sums = Mat::zeros(k, dim);

    for it in 0..params.max_iters {
        iters = it + 1;
        // Assign (parallel over fixed row chunks).
        let cost = assign_rows(x, &centroids, &mut assignment, &params.exec);
        // Update: per-chunk partial sums/counts in parallel, merged in
        // fixed chunk order — bitwise independent of the thread count.
        let update_span = crate::obs::span(&crate::obs::KMEANS_UPDATE);
        {
            let assignment = &assignment;
            let row_ranges = &row_ranges;
            params.exec.for_chunks(&stripe_ranges, &mut partials, stride, |c, _, out| {
                out.fill(0.0);
                let (psums, pcounts) = out.split_at_mut(k * dim);
                for i in row_ranges[c].clone() {
                    let cl = assignment[i];
                    pcounts[cl] += 1.0;
                    let dst = &mut psums[cl * dim..(cl + 1) * dim];
                    for (s, v) in dst.iter_mut().zip(x.row(i)) {
                        *s += v;
                    }
                }
            });
        }
        counts.fill(0);
        sums.data.fill(0.0);
        for part in partials.chunks_exact(stride) {
            let (psums, pcounts) = part.split_at(k * dim);
            for (cnt, p) in counts.iter_mut().zip(pcounts) {
                *cnt += *p as usize;
            }
            for (s, p) in sums.data.iter_mut().zip(psums) {
                *s += p;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Empty cluster: reseed at the point farthest from its
                // centroid (standard fix).
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = dist2(x.row(a), centroids.row(assignment[a]));
                        let db = dist2(x.row(b), centroids.row(assignment[b]));
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(x.row(far));
            } else {
                let inv = 1.0 / counts[c] as f64;
                for (cv, sv) in centroids.row_mut(c).iter_mut().zip(sums.row(c)) {
                    *cv = sv * inv;
                }
            }
        }
        drop(update_span);
        if (prev_cost - cost).abs() <= params.tol * prev_cost.max(1e-300) {
            break;
        }
        prev_cost = cost;
    }
    // Final assignment/cost against the last centroids.
    let cost = assign_rows(x, &centroids, &mut assignment, &params.exec);
    KmeansResult { assignment, centroids, cost, iters }
}

/// The assignment step: nearest centroid per row of `x`, written into
/// `assignment`, returning the summed squared distance. Each chunk's
/// rows are processed exactly as in the serial loop; the total cost is
/// folded over chunks in chunk order, so the result does not depend on
/// `exec.threads`.
fn assign_rows(x: &Mat, centroids: &Mat, assignment: &mut [usize], exec: &ExecPolicy) -> f64 {
    let n = x.rows;
    if n == 0 {
        return 0.0;
    }
    let _span = crate::obs::span(&crate::obs::KMEANS_ASSIGN);
    let ranges = par::even_ranges(n, par::fixed_chunks(n, ASSIGN_ROWS_PER_CHUNK));
    exec.map_chunks(&ranges, assignment, 1, |_, rows, out| {
        let mut chunk_cost = 0.0;
        for (slot, i) in out.iter_mut().zip(rows) {
            let (best, d2) = nearest(x.row(i), centroids);
            *slot = best;
            chunk_cost += d2;
        }
        chunk_cost
    })
    .iter()
    .sum()
}

fn kmeanspp_init(x: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = x.rows;
    let mut centroids = Mat::zeros(k, x.cols);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut d2 = vec![0.0f64; n];
    for i in 0..n {
        d2[i] = dist2(x.row(i), centroids.row(0));
    }
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            // Sample proportional to squared distance.
            let mut target = rng.f64() * total;
            let mut chosen = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    chosen = i;
                    break;
                }
                target -= w;
            }
            chosen
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
        for i in 0..n {
            d2[i] = d2[i].min(dist2(x.row(i), centroids.row(c)));
        }
    }
    centroids
}

#[inline]
fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn nearest(row: &[f64], centroids: &Mat) -> (usize, f64) {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for c in 0..centroids.rows {
        let d = dist2(row, centroids.row(c));
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    (best, best_d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::gaussian_mixture;
    use crate::testing::prop::{check, forall};

    #[test]
    fn recovers_separated_gaussian_clusters() {
        let mut rng = Rng::new(191);
        let (pts, labels) = gaussian_mixture(&mut rng, 300, 4, 3, 12.0);
        let x = Mat::from_vec(300, 4, pts);
        let res = kmeans(&x, &KmeansParams { k: 3, ..Default::default() }, &mut rng);
        // Clustering should agree with ground truth up to permutation:
        // check pairs.
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..300 {
            for j in 0..i {
                total += 1;
                let same_true = labels[i] == labels[j];
                let same_got = res.assignment[i] == res.assignment[j];
                if same_true == same_got {
                    agree += 1;
                }
            }
        }
        let rate = agree as f64 / total as f64;
        assert!(rate > 0.97, "pair agreement {rate}");
    }

    #[test]
    fn cost_decreases_with_more_clusters() {
        forall(
            192,
            6,
            |r| Mat::randn(r, 80, 3),
            |x| {
                let mut r1 = Rng::new(5);
                let c2 = kmeans(x, &KmeansParams { k: 2, ..Default::default() }, &mut r1).cost;
                let mut r2 = Rng::new(5);
                let c8 = kmeans(x, &KmeansParams { k: 8, ..Default::default() }, &mut r2).cost;
                check(c8 <= c2 + 1e-9, format!("k=8 cost {c8} > k=2 cost {c2}"))
            },
        );
    }

    #[test]
    fn k_one_gives_total_variance() {
        let mut rng = Rng::new(193);
        let x = Mat::randn(&mut rng, 50, 2);
        let res = kmeans(&x, &KmeansParams { k: 1, ..Default::default() }, &mut rng);
        // Centroid = mean; cost = sum of squared deviations.
        let mut mean = vec![0.0; 2];
        for i in 0..50 {
            for (m, v) in mean.iter_mut().zip(x.row(i)) {
                *m += v / 50.0;
            }
        }
        let want: f64 = (0..50).map(|i| dist2(x.row(i), &mean)).sum();
        assert!((res.cost - want).abs() < 1e-9);
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::new(194);
        let x = Mat::randn(&mut rng, 5, 2);
        let res = kmeans(&x, &KmeansParams { k: 50, ..Default::default() }, &mut rng);
        assert!(res.cost < 1e-18, "each point its own cluster, cost {}", res.cost);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // n > ASSIGN_ROWS_PER_CHUNK so the cost reduction really folds
        // over several chunks.
        let x = Mat::randn(&mut Rng::new(8), 3000, 4);
        let run = |threads: usize| {
            let mut rng = Rng::new(9);
            let p = KmeansParams {
                k: 6,
                exec: ExecPolicy::with_threads(threads),
                ..Default::default()
            };
            kmeans(&x, &p, &mut rng)
        };
        let base = run(1);
        for threads in [2usize, 4] {
            let got = run(threads);
            assert_eq!(base.assignment, got.assignment, "{threads} threads");
            assert_eq!(base.cost.to_bits(), got.cost.to_bits(), "{threads} threads");
            assert_eq!(base.iters, got.iters, "{threads} threads");
            assert_eq!(base.centroids.data, got.centroids.data, "{threads} threads");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let x = Mat::randn(&mut Rng::new(1), 60, 3);
        let a = kmeans(&x, &KmeansParams { k: 4, ..Default::default() }, &mut r1);
        let b = kmeans(&x, &KmeansParams { k: 4, ..Default::default() }, &mut r2);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.cost, b.cost);
    }
}
