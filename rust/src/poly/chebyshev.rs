//! Chebyshev-series fitting — the §4 alternative prior
//! `p(λ) ∝ 1/√(1−λ²)`, known for uniform (minimax-like) convergence.
//! Used by ablation A1 to compare against the Legendre default.

use super::{Basis, Series};

/// Chebyshev basis values T(0..=order, x).
pub fn basis(x: f64, order: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(order + 1);
    out.push(1.0);
    if order == 0 {
        return out;
    }
    out.push(x);
    for r in 2..=order {
        let t = 2.0 * x * out[r - 1] - out[r - 2];
        out.push(t);
    }
    out
}

/// Fit f by Chebyshev–Gauss quadrature with `npts` nodes:
/// `a_k = (2 − δ_{k0})/N · Σ_j f(cos θ_j) cos(k θ_j)`,
/// `θ_j = π (j + 1/2) / N`.
pub fn fit(f: impl Fn(f64) -> f64, order: usize, npts: usize) -> Series {
    let n = npts.max(order + 1);
    let mut coeffs = vec![0.0; order + 1];
    for j in 0..n {
        let theta = std::f64::consts::PI * (j as f64 + 0.5) / n as f64;
        let fx = f(theta.cos());
        if fx == 0.0 {
            continue;
        }
        for (k, c) in coeffs.iter_mut().enumerate() {
            *c += fx * (k as f64 * theta).cos();
        }
    }
    for (k, c) in coeffs.iter_mut().enumerate() {
        *c *= if k == 0 { 1.0 } else { 2.0 } / n as f64;
    }
    Series { basis: Basis::Chebyshev, coeffs }
}

/// Exact Chebyshev coefficients for the step f = I(x ≥ c): with
/// `θc = arccos c`, f(cos θ) = 1 on θ ∈ [0, θc], so
/// `a_0 = θc/π`, `a_k = 2 sin(k θc)/(k π)`.
pub fn step_coeffs(order: usize, c: f64) -> Series {
    let c = c.clamp(-1.0, 1.0);
    let theta_c = c.acos();
    let mut coeffs = vec![0.0; order + 1];
    coeffs[0] = theta_c / std::f64::consts::PI;
    for k in 1..=order {
        coeffs[k] = 2.0 * (k as f64 * theta_c).sin() / (k as f64 * std::f64::consts::PI);
    }
    Series { basis: Basis::Chebyshev, coeffs }
}

/// Jackson damping factors g_k — multiply onto step/band coefficients to
/// suppress Gibbs oscillation (kernel-polynomial method [25]).
pub fn jackson_damping(order: usize) -> Vec<f64> {
    let np = order as f64 + 2.0;
    (0..=order)
        .map(|k| {
            let kf = k as f64;
            let a = (np - kf) * (std::f64::consts::PI * kf / np).cos();
            let b = (std::f64::consts::PI / np).tan().recip() * (std::f64::consts::PI * kf / np).sin();
            (a + b) / np
        })
        .collect()
}

/// Apply damping factors to a series (returns a damped copy).
pub fn damped(s: &Series, factors: &[f64]) -> Series {
    assert_eq!(s.coeffs.len(), factors.len());
    Series {
        basis: s.basis,
        coeffs: s.coeffs.iter().zip(factors).map(|(c, g)| c * g).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{all_close, check, forall};

    #[test]
    fn basis_known_values() {
        let x = 0.3;
        let b = basis(x, 3);
        assert!((b[2] - (2.0 * x * x - 1.0)).abs() < 1e-14);
        assert!((b[3] - (4.0 * x.powi(3) - 3.0 * x)).abs() < 1e-14);
    }

    #[test]
    fn basis_is_cosine_of_multiples() {
        forall(
            91,
            64,
            |r| r.uniform(-1.0, 1.0),
            |&x| {
                let theta = x.acos();
                for (k, t) in basis(x, 12).iter().enumerate() {
                    check(
                        (t - (k as f64 * theta).cos()).abs() < 1e-10,
                        format!("T_{k}({x})"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn step_coeffs_match_quadrature() {
        forall(
            92,
            10,
            |r| (r.uniform(-0.9, 0.9), 2 + r.below(30)),
            |&(c, order)| {
                let exact = step_coeffs(order, c);
                let quad = fit(|x| if x >= c { 1.0 } else { 0.0 }, order, 20_000);
                all_close(&exact.coeffs, &quad.coeffs, 1e-3)
            },
        );
    }

    #[test]
    fn fit_smooth_converges_fast() {
        let f = |x: f64| x.exp();
        let e4 = fit(f, 4, 256).max_err(f, 1001);
        let e12 = fit(f, 12, 256).max_err(f, 1001);
        assert!(e12 < 1e-9 && e12 < e4 * 1e-3);
    }

    #[test]
    fn fit_reproduces_chebyshev_polynomial() {
        let f = |x: f64| 4.0 * x.powi(3) - 3.0 * x; // T_3
        let s = fit(f, 5, 64);
        let mut want = vec![0.0; 6];
        want[3] = 1.0;
        all_close(&s.coeffs, &want, 1e-12).unwrap();
    }

    #[test]
    fn jackson_damping_shape() {
        let g = jackson_damping(16);
        assert!((g[0] - 1.0).abs() < 1e-9, "g0 = {}", g[0]);
        // Monotone decreasing toward ~0.
        for w in g.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!(g[16] < 0.05);
    }

    #[test]
    fn damped_step_suppresses_overshoot() {
        let c = 0.2;
        let f = |x: f64| if x >= c { 1.0 } else { 0.0 };
        let raw = step_coeffs(40, c);
        let dam = damped(&raw, &jackson_damping(40));
        // Gibbs overshoot: raw max error ~0.5 near jump stays, but the
        // *plateau* oscillation away from the jump shrinks.
        let plateau_err = |s: &Series| {
            (0..200)
                .map(|i| -1.0 + i as f64 * (c - 0.15 + 1.0) / 200.0)
                .map(|x| (f(x) - s.eval(x)).abs())
                .fold(0.0, f64::max)
        };
        assert!(plateau_err(&dam) < plateau_err(&raw));
    }
}
