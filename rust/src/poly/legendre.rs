//! Legendre-series fitting (the paper's Algorithm 1 coefficients).
//!
//! `a(r) = (r + 1/2) ∫_{-1}^{1} p(r, x) f(x) dx`, minimizing the uniform-
//! prior L2 error Δ_L. Indicator functions get **exact** coefficients via
//! the primitive identity `∫ p_r = (p_{r+1} − p_{r−1})/(2r+1)`; general f
//! uses composite Gauss–Legendre quadrature.

use super::{Basis, Series};

/// Legendre basis values p(0..=order, x).
pub fn basis(x: f64, order: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(order + 1);
    out.push(1.0);
    if order == 0 {
        return out;
    }
    out.push(x);
    for r in 2..=order {
        let rf = r as f64;
        let p = (2.0 - 1.0 / rf) * x * out[r - 1] - (1.0 - 1.0 / rf) * out[r - 2];
        out.push(p);
    }
    out
}

/// Exact coefficients for the indicator f(x) = I(a ≤ x ≤ b), a,b ∈ [-1,1].
pub fn indicator_coeffs(order: usize, a: f64, b: f64) -> Series {
    let a = a.clamp(-1.0, 1.0);
    let b = b.clamp(-1.0, 1.0);
    let mut coeffs = vec![0.0; order + 1];
    if b > a {
        let pa = basis(a, order + 1);
        let pb = basis(b, order + 1);
        coeffs[0] = 0.5 * (b - a);
        for r in 1..=order {
            let prim_b = (pb[r + 1] - pb[r - 1]) / (2.0 * r as f64 + 1.0);
            let prim_a = (pa[r + 1] - pa[r - 1]) / (2.0 * r as f64 + 1.0);
            coeffs[r] = (r as f64 + 0.5) * (prim_b - prim_a);
        }
    }
    Series { basis: Basis::Legendre, coeffs }
}

/// Exact coefficients for the step f(x) = I(x ≥ c).
pub fn step_coeffs(order: usize, c: f64) -> Series {
    indicator_coeffs(order, c, 1.0)
}

// 8-point Gauss–Legendre nodes/weights on [-1, 1] (Abramowitz & Stegun).
const GL8_X: [f64; 8] = [
    -0.960_289_856_497_536_2,
    -0.796_666_477_413_626_7,
    -0.525_532_409_916_329_0,
    -0.183_434_642_495_649_8,
    0.183_434_642_495_649_8,
    0.525_532_409_916_329_0,
    0.796_666_477_413_626_7,
    0.960_289_856_497_536_2,
];
const GL8_W: [f64; 8] = [
    0.101_228_536_290_376_26,
    0.222_381_034_453_374_47,
    0.313_706_645_877_887_3,
    0.362_683_783_378_362_0,
    0.362_683_783_378_362_0,
    0.313_706_645_877_887_3,
    0.222_381_034_453_374_47,
    0.101_228_536_290_376_26,
];

/// Fit arbitrary f by composite 8-point Gauss quadrature over `panels`
/// uniform panels of [-1, 1].
pub fn fit(f: impl Fn(f64) -> f64, order: usize, panels: usize) -> Series {
    let mut coeffs = vec![0.0; order + 1];
    let h = 2.0 / panels as f64;
    for p in 0..panels {
        let lo = -1.0 + p as f64 * h;
        let mid = lo + h / 2.0;
        for (node, w) in GL8_X.iter().zip(GL8_W.iter()) {
            let x = mid + node * h / 2.0;
            let fx = f(x);
            if fx == 0.0 {
                continue;
            }
            let ps = basis(x, order);
            let scale = w * h / 2.0 * fx;
            for (r, pv) in ps.iter().enumerate() {
                coeffs[r] += scale * pv;
            }
        }
    }
    for (r, c) in coeffs.iter_mut().enumerate() {
        *c *= r as f64 + 0.5;
    }
    Series { basis: Basis::Legendre, coeffs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{all_close, check, forall};

    #[test]
    fn basis_first_few_polynomials() {
        let x = 0.4;
        let b = basis(x, 4);
        assert!((b[0] - 1.0).abs() < 1e-15);
        assert!((b[1] - x).abs() < 1e-15);
        assert!((b[2] - (1.5 * x * x - 0.5)).abs() < 1e-14);
        assert!((b[3] - (2.5 * x.powi(3) - 1.5 * x)).abs() < 1e-14);
        assert!((b[4] - (4.375 * x.powi(4) - 3.75 * x * x + 0.375)).abs() < 1e-14);
    }

    #[test]
    fn basis_bounded_by_one_on_interval() {
        forall(
            81,
            128,
            |r| r.uniform(-1.0, 1.0),
            |&x| {
                for (r, p) in basis(x, 30).iter().enumerate() {
                    check(p.abs() <= 1.0 + 1e-12, format!("|P_{r}({x})| = {p}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn step_coeffs_match_quadrature() {
        forall(
            82,
            12,
            |r| (r.uniform(-0.9, 0.9), 1 + r.below(25)),
            |&(c, order)| {
                let exact = step_coeffs(order, c);
                let quad = fit(|x| if x >= c { 1.0 } else { 0.0 }, order, 4096);
                all_close(&exact.coeffs, &quad.coeffs, 1e-3)
            },
        );
    }

    #[test]
    fn full_interval_step_is_constant_one() {
        let s = step_coeffs(12, -1.0);
        assert!((s.coeffs[0] - 1.0).abs() < 1e-14);
        assert!(s.coeffs[1..].iter().all(|c| c.abs() < 1e-14));
    }

    #[test]
    fn empty_interval_is_zero() {
        let s = indicator_coeffs(10, 0.5, 0.4);
        assert!(s.coeffs.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn band_is_difference_of_steps() {
        let band = indicator_coeffs(20, -0.3, 0.6);
        let lo = step_coeffs(20, -0.3);
        let hi = step_coeffs(20, 0.6);
        let diff: Vec<f64> = lo.coeffs.iter().zip(&hi.coeffs).map(|(a, b)| a - b).collect();
        all_close(&band.coeffs, &diff, 1e-12).unwrap();
    }

    #[test]
    fn fit_reproduces_polynomial_exactly() {
        // f already a polynomial of degree <= order: fit must recover it.
        let f = |x: f64| 3.0 * x * x - x + 0.5;
        let s = fit(f, 4, 32);
        assert!(s.max_err(f, 501) < 1e-10);
    }

    #[test]
    fn fit_smooth_function_converges() {
        let f = |x: f64| (2.0 * x).sin();
        let e4 = fit(f, 4, 64).max_err(f, 1001);
        let e12 = fit(f, 12, 64).max_err(f, 1001);
        assert!(e12 < e4 * 1e-3, "e4={e4} e12={e12}");
        assert!(e12 < 1e-9);
    }

    #[test]
    fn step_series_value_at_plateaus() {
        // Away from the jump, the truncated series approaches 0 / 1.
        let s = step_coeffs(120, 0.2);
        assert!((s.eval(0.8) - 1.0).abs() < 0.02);
        assert!(s.eval(-0.6).abs() < 0.02);
    }
}
