//! §4 "denoising by cascading": approximate `f` as `(g̃_{L/b})^b` with
//! `g = f^{1/b}`, so the `x^b` non-linearity re-sharpens the nulls that a
//! single order-L fit would blur.

use super::Series;
use crate::funcs::SpectralFn;
use crate::poly::{chebyshev, legendre, Basis};

/// Real b-th root for non-negative inputs (cascading stage function).
pub fn nth_root_nonneg(v: f64, b: usize) -> f64 {
    debug_assert!(v >= 0.0 && b >= 1);
    match b {
        1 => v,
        2 => v.sqrt(),
        _ => v.powf(1.0 / b as f64),
    }
}

/// A cascade plan: run the stage series `b` times.
#[derive(Clone, Debug)]
pub struct CascadePlan {
    /// Series approximating g = f^{1/b} at order ~L/b.
    pub stage: Series,
    /// Number of applications b.
    pub b: usize,
}

impl CascadePlan {
    /// Output-array passes per recurrence iteration with the fused
    /// `y = c1·(S·x) − c2·z` kernel ([`Operator::apply_axpby_into_ws`]):
    /// the SpMM, the scale and the subtract land in one sweep.
    ///
    /// [`Operator::apply_axpby_into_ws`]: crate::embed::op::Operator::apply_axpby_into_ws
    pub const FUSED_STEP_PASSES: usize = 1;
    /// Passes the pre-fusion kernel needed per recurrence iteration
    /// (SpMM write, c1-scale read/write, c2-subtract read/write).
    pub const UNFUSED_STEP_PASSES: usize = 3;

    /// Total matrix-vector products per starting vector (= b * stage order).
    pub fn total_matvecs(&self) -> usize {
        self.b * self.stage.order()
    }

    /// Fused recurrence steps per cascade stage: every term past the
    /// linear one (orders 2..=L) is produced by one fused
    /// scale-and-subtract pass instead of [`Self::UNFUSED_STEP_PASSES`]
    /// separate sweeps.
    pub fn fused_steps_per_stage(&self) -> usize {
        self.stage.order().saturating_sub(1)
    }

    /// Effective end-to-end function value: (g̃(x))^b.
    pub fn eval(&self, x: f64) -> f64 {
        self.stage.eval(x).powi(self.b as i32)
    }

    /// End-to-end max deviation from f on a grid.
    pub fn max_err(&self, f: impl Fn(f64) -> f64, grid: usize) -> f64 {
        (0..grid)
            .map(|i| -1.0 + 2.0 * i as f64 / (grid - 1) as f64)
            .map(|x| (f(x) - self.eval(x)).abs())
            .fold(0.0, f64::max)
    }
}

/// Build a cascade plan for `f` with total matvec budget `order` split
/// into `b` stages (paper uses b=2 for the DBLP/Amazon experiments).
/// Indicators use closed-form stage coefficients (f^{1/b} = f); other f
/// are fit by quadrature on f^{1/b}.
pub fn plan(f: &SpectralFn, order: usize, b: usize, basis: Basis) -> CascadePlan {
    assert!(b >= 1, "cascade factor must be >= 1");
    let stage_order = (order / b).max(1);
    let stage = match (f, basis) {
        (SpectralFn::Step { c }, Basis::Legendre) => legendre::step_coeffs(stage_order, *c),
        (SpectralFn::Step { c }, Basis::Chebyshev) => chebyshev::step_coeffs(stage_order, *c),
        (SpectralFn::Band { a, b: hi }, Basis::Legendre) => {
            legendre::indicator_coeffs(stage_order, *a, *hi)
        }
        (g, Basis::Legendre) => legendre::fit(|x| g.eval_root(x, b), stage_order, 512),
        (g, Basis::Chebyshev) => chebyshev::fit(|x| g.eval_root(x, b), stage_order, 8192),
    };
    CascadePlan { stage, b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, close, forall};

    #[test]
    fn nth_root_inverts_power() {
        forall(
            101,
            64,
            |r| (r.uniform(0.0, 5.0), 1 + r.below(4)),
            |&(v, b)| close(nth_root_nonneg(v, b).powi(b as i32), v, 1e-10),
        );
    }

    #[test]
    fn plan_splits_budget() {
        let f = SpectralFn::Step { c: 0.5 };
        let p = plan(&f, 120, 2, Basis::Legendre);
        assert_eq!(p.stage.order(), 60);
        assert_eq!(p.total_matvecs(), 120);
        let p1 = plan(&f, 120, 1, Basis::Legendre);
        assert_eq!(p1.stage.order(), 120);
    }

    #[test]
    fn fused_step_accounting() {
        let p = plan(&SpectralFn::Step { c: 0.5 }, 40, 2, Basis::Legendre);
        // Stage order 20 → 19 recurrence steps (orders 2..=20), each one
        // fused output pass instead of three.
        assert_eq!(p.fused_steps_per_stage(), 19);
        assert!(CascadePlan::FUSED_STEP_PASSES < CascadePlan::UNFUSED_STEP_PASSES);
        assert_eq!(CascadePlan::FUSED_STEP_PASSES, 1);
    }

    #[test]
    fn cascade_improves_null_suppression_for_step() {
        // The paper's Figure 1b effect, at function level: evaluate the
        // end-to-end approximation of I(x >= 0.9) in the null region.
        let f = SpectralFn::Step { c: 0.9 };
        let null_leak = |p: &CascadePlan| -> f64 {
            (0..800)
                .map(|i| -1.0 + i as f64 * 1.7 / 800.0) // x in [-1, 0.7]
                .map(|x| p.eval(x).abs())
                .fold(0.0, f64::max)
        };
        let b1 = plan(&f, 80, 1, Basis::Legendre);
        let b2 = plan(&f, 80, 2, Basis::Legendre);
        assert!(
            null_leak(&b2) < null_leak(&b1),
            "b2 leak {} !< b1 leak {}",
            null_leak(&b2),
            null_leak(&b1)
        );
    }

    #[test]
    fn cascade_preserves_passband_for_step() {
        let f = SpectralFn::Step { c: 0.8 };
        let p = plan(&f, 120, 2, Basis::Legendre);
        // Well inside the passband the cascade should give ~1.
        for &x in &[0.95, 0.99] {
            check((p.eval(x) - 1.0).abs() < 0.15, format!("passband at {x}: {}", p.eval(x)))
                .unwrap();
        }
    }

    #[test]
    fn smooth_function_cascade_recomposes() {
        // f = ((x+1)/2)^2 with b=2: g = (x+1)/2 is exactly order-1.
        let f = SpectralFn::Diffusion { t: 1.0 }; // exp(x-1): g = exp((x-1)/2)
        let p = plan(&f, 16, 2, Basis::Legendre);
        let err = p.max_err(|x| f.eval(x), 501);
        assert!(err < 1e-6, "cascade err {err}");
    }
}
