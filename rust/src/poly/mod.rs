//! Polynomial approximation of spectral weighing functions (paper §3.4).
//!
//! * [`legendre`] — the paper's choice: fit minimizing
//!   `∫|f − f̃_L|²dx` (uniform eigenvalue prior) via Legendre series, with
//!   **closed-form** coefficients for the step/band indicators the
//!   experiments use.
//! * [`chebyshev`] — the §4 alternative (`p(λ) ∝ 1/√(1−λ²)` prior),
//!   implemented for the ablation A1.
//! * [`cascade`] — §4 "denoising by cascading": split f into b stages of
//!   g = f^{1/b} at order L/b.
//!
//! Both bases share the same three-term matrix recursion driver in
//! `crate::embed`; a [`Series`] carries its own recursion scalars.

pub mod cascade;
pub mod chebyshev;
pub mod legendre;

/// Which orthogonal basis a series is expressed in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Basis {
    Legendre,
    Chebyshev,
}

/// A truncated orthogonal-polynomial series `sum_r a(r) p(r, x)`.
#[derive(Clone, Debug)]
pub struct Series {
    pub basis: Basis,
    pub coeffs: Vec<f64>,
}

impl Series {
    pub fn order(&self) -> usize {
        self.coeffs.len().saturating_sub(1)
    }

    /// Recursion scalars (c1(r), c2(r)) with
    /// `p(r, x) = c1(r)·x·p(r−1, x) − c2(r)·p(r−2, x)`, r ≥ 2.
    /// (Both bases have p(0)=1; Legendre p(1)=x, Chebyshev T(1)=x.)
    pub fn recursion_scalars(&self, r: usize) -> (f64, f64) {
        debug_assert!(r >= 2);
        match self.basis {
            Basis::Legendre => (2.0 - 1.0 / r as f64, 1.0 - 1.0 / r as f64),
            Basis::Chebyshev => (2.0, 1.0),
        }
    }

    /// Pointwise evaluation of the series.
    pub fn eval(&self, x: f64) -> f64 {
        if self.coeffs.is_empty() {
            return 0.0;
        }
        let mut acc = self.coeffs[0];
        if self.coeffs.len() == 1 {
            return acc;
        }
        let (mut p_prev2, mut p_prev) = (1.0, x);
        acc += self.coeffs[1] * p_prev;
        for r in 2..self.coeffs.len() {
            let (c1, c2) = self.recursion_scalars(r);
            let p = c1 * x * p_prev - c2 * p_prev2;
            acc += self.coeffs[r] * p;
            p_prev2 = p_prev;
            p_prev = p;
        }
        acc
    }

    /// `δ = max_x |f(x) − f̃_L(x)|` on a uniform grid — the additive
    /// distortion bound of Theorem 1.
    pub fn max_err(&self, f: impl Fn(f64) -> f64, grid: usize) -> f64 {
        (0..grid)
            .map(|i| -1.0 + 2.0 * i as f64 / (grid - 1) as f64)
            .map(|x| (f(x) - self.eval(x)).abs())
            .fold(0.0, f64::max)
    }

    /// RMS error on a uniform grid (∝ √Δ_L of §3.4).
    pub fn rms_err(&self, f: impl Fn(f64) -> f64, grid: usize) -> f64 {
        let s: f64 = (0..grid)
            .map(|i| -1.0 + 2.0 * i as f64 / (grid - 1) as f64)
            .map(|x| {
                let e = f(x) - self.eval(x);
                e * e
            })
            .sum();
        (s / grid as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_constant_and_linear() {
        let s = Series { basis: Basis::Legendre, coeffs: vec![2.0] };
        assert_eq!(s.eval(0.3), 2.0);
        let s = Series { basis: Basis::Legendre, coeffs: vec![1.0, 2.0] };
        assert!((s.eval(0.5) - 2.0).abs() < 1e-12); // 1 + 2*0.5
    }

    #[test]
    fn empty_series_is_zero() {
        let s = Series { basis: Basis::Chebyshev, coeffs: vec![] };
        assert_eq!(s.eval(0.7), 0.0);
        assert_eq!(s.order(), 0);
    }

    #[test]
    fn eval_matches_direct_basis_combination() {
        // sum over explicitly computed basis polynomials.
        let coeffs = vec![0.5, -1.0, 2.0, 0.25];
        for &basis in &[Basis::Legendre, Basis::Chebyshev] {
            let s = Series { basis, coeffs: coeffs.clone() };
            for i in 0..21 {
                let x = -1.0 + 0.1 * i as f64;
                // direct recursion
                let mut ps = vec![1.0, x];
                for r in 2..coeffs.len() {
                    let (c1, c2) = s.recursion_scalars(r);
                    let p = c1 * x * ps[r - 1] - c2 * ps[r - 2];
                    ps.push(p);
                }
                let want: f64 = coeffs.iter().zip(&ps).map(|(a, p)| a * p).sum();
                assert!((s.eval(x) - want).abs() < 1e-12);
            }
        }
    }
}
