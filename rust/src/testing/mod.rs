//! In-tree property-testing harness (proptest is unavailable offline).
//!
//! [`prop::forall`] runs a property over `cases` randomly generated inputs
//! from a seeded [`crate::util::rng::Rng`]; on failure it reports the case
//! index and the seed that reproduces it. Generators are plain closures
//! `Fn(&mut Rng) -> T`, composed with ordinary Rust.

pub mod gen;
pub mod prop;
