//! Random-input generators for property tests.

use crate::util::rng::Rng;

/// Uniform usize in `[lo, hi]`.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Uniform f64 in `[lo, hi)`.
pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
    rng.uniform(lo, hi)
}

/// Vector of standard normals.
pub fn normal_vec(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Row-major dense symmetric matrix with spectral norm <= 1 (approximately;
/// scaled by a power-iteration estimate then a safety factor).
pub fn sym_contraction(rng: &mut Rng, n: usize) -> Vec<f64> {
    let mut a = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let v = rng.normal();
            a[i * n + j] = v;
            a[j * n + i] = v;
        }
    }
    // Rough norm bound: Frobenius norm >= spectral norm, so dividing by it
    // guarantees a contraction.
    let fro = a.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
    for v in a.iter_mut() {
        *v /= fro;
    }
    a
}

/// Random sparse symmetric adjacency as an edge list (no self loops, no
/// duplicates), Erdős–Rényi-ish with expected degree `deg`.
pub fn random_edges(rng: &mut Rng, n: usize, deg: f64) -> Vec<(usize, usize)> {
    let m_target = ((n as f64 * deg) / 2.0) as usize;
    let mut seen = std::collections::HashSet::new();
    let mut edges = Vec::new();
    let mut attempts = 0;
    while edges.len() < m_target && attempts < 20 * m_target.max(8) {
        attempts += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edges.push(key);
        }
    }
    edges
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sym_contraction_is_symmetric_and_bounded() {
        let mut rng = Rng::new(1);
        let n = 12;
        let a = sym_contraction(&mut rng, n);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(a[i * n + j], a[j * n + i]);
            }
        }
        let fro: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(fro <= 1.0 + 1e-9);
    }

    #[test]
    fn random_edges_valid() {
        let mut rng = Rng::new(2);
        let edges = random_edges(&mut rng, 50, 4.0);
        let set: std::collections::HashSet<_> = edges.iter().collect();
        assert_eq!(set.len(), edges.len(), "no duplicates");
        for &(u, v) in &edges {
            assert!(u < v && v < 50);
        }
    }
}
