//! The `forall` runner.

use crate::util::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 64;

/// Run `property` over `cases` inputs drawn by `generator` from a stream
/// seeded with `seed`. Panics with a reproducible report on first failure.
///
/// The property returns `Result<(), String>` so failures carry a message;
/// use [`check`] to adapt bool-returning properties.
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    generator: impl Fn(&mut Rng) -> T,
    property: impl Fn(&T) -> Result<(), String>,
) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        // Each case gets its own child stream so a failing case is
        // reproducible in isolation from (seed, case).
        let mut rng = root.split(case as u64);
        let input = generator(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property failed (seed={seed}, case={case}/{cases}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Adapt a boolean condition into a property result.
pub fn check(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert two floats are within `tol` (absolute + relative mix).
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("|{a} - {b}| = {} > {tol} (scaled)", (a - b).abs()))
    }
}

/// Assert element-wise closeness of two slices.
pub fn all_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        close(x, y, tol).map_err(|e| format!("at index {i}: {e}"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0usize);
        let counter = &mut count;
        forall(
            1,
            32,
            |r| r.below(100),
            |&x| {
                counter.set(counter.get() + 1);
                check(x < 100, "in range")
            },
        );
        assert_eq!(count.get(), 32);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(2, 16, |r| r.below(10), |&x| check(x < 5, format!("{x} >= 5")));
    }

    #[test]
    fn close_handles_scales() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1e9, 1e9 + 1.0, 1e-6).is_ok()); // relative
        assert!(close(0.0, 1e-3, 1e-6).is_err());
    }

    #[test]
    fn all_close_reports_index() {
        let e = all_close(&[1.0, 2.0], &[1.0, 3.0], 1e-9).unwrap_err();
        assert!(e.contains("index 1"), "{e}");
        assert!(all_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }
}
