//! Tile operators backed by AOT artifacts.
//!
//! [`PjrtStepOp`] wraps the fused Pallas recursion-step kernel
//! (`legendre_step_{n}x{d}`): `Q_r = c1·(S@Q_{r-1}) − c2·Q_{r-2}`. The
//! Rust loop supplies (c1, c2, a_r) per step, so one compiled executable
//! serves any order, basis and weighing function. With (c1, c2) = (1, 0)
//! it doubles as a plain `S@Q` [`Operator`], which lets every native
//! driver (power iteration, FastEmbed, Lanczos) run on the PJRT path.
//!
//! [`GaussKernelOp`] wraps `gauss_matvec_{l}x{f}x{d}`: the implicit
//! Gaussian-kernel product `K@Q` with K never materialized (kernel PCA).

use std::sync::Arc;

use anyhow::{Context, Result};

use super::artifacts::Artifacts;
use super::client::{literal_from_mat, literal_vec, mat_from_literal, Runtime};
use crate::embed::op::Operator;
use crate::linalg::Mat;
use crate::par::ExecPolicy;
use crate::poly::Series;

/// Dense-tile recursion operator over the AOT step kernel.
pub struct PjrtStepOp {
    rt: Arc<Runtime>,
    exe: Arc<xla::PjRtLoadedExecutable>,
    /// S tile, uploaded once per operator.
    s_lit: xla::Literal,
    pub n: usize,
    pub d: usize,
    nnz: usize,
}

impl PjrtStepOp {
    /// Build from the registry: finds `legendre_step_{n}x{d}`, validates
    /// that `s` matches the baked tile shape.
    pub fn new(rt: Arc<Runtime>, arts: &Artifacts, s: &Mat) -> Result<PjrtStepOp> {
        let info = arts
            .find_prefix("legendre_step")
            .context("no legendre_step artifact in manifest")?;
        let (n, d) = (info.params[0][0], info.params[1][1]);
        anyhow::ensure!(
            s.rows == n && s.cols == n,
            "operator tile is {}x{}, artifact baked for {n}x{n}",
            s.rows,
            s.cols
        );
        let exe = rt.load_hlo_text(&info.file)?;
        let s_lit = literal_from_mat(s)?;
        Ok(PjrtStepOp { rt, exe, s_lit, n, d, nnz: n * n })
    }

    /// One fused step: `c1·(S@q_prev) − c2·q_prev2`.
    pub fn step(&self, q_prev: &Mat, q_prev2: &Mat, c1: f64, c2: f64) -> Result<Mat> {
        anyhow::ensure!(
            q_prev.rows == self.n && q_prev.cols == self.d,
            "block is {}x{}, artifact baked for {}x{}",
            q_prev.rows,
            q_prev.cols,
            self.n,
            self.d
        );
        let qp = literal_from_mat(q_prev)?;
        let qpp = literal_from_mat(q_prev2)?;
        let c = literal_vec(&[c1 as f32, c2 as f32]);
        let out = self
            .rt
            .execute_tuple1(&self.exe, &[self.s_lit.clone(), qp, qpp, c])?;
        mat_from_literal(&out, self.n, self.d)
    }

    /// Full series application driven from Rust: the AOT analogue of
    /// `embed::fastembed::apply_series`, one PJRT dispatch per step.
    pub fn apply_series(&self, series: &Series, q0: &Mat, matvecs: &mut usize) -> Result<Mat> {
        let a = &series.coeffs;
        anyhow::ensure!(!a.is_empty(), "empty series");
        let mut e = q0.clone();
        e.scale(a[0]);
        if a.len() == 1 {
            return Ok(e);
        }
        // q1 = S q0 via the step kernel with (c1, c2) = (1, 0).
        let zero = Mat::zeros(q0.rows, q0.cols);
        let mut q_prev2 = q0.clone();
        let mut q_prev = self.step(q0, &zero, 1.0, 0.0)?;
        *matvecs += q0.cols;
        e.axpy(a[1], &q_prev);
        for r in 2..a.len() {
            let (c1, c2) = series.recursion_scalars(r);
            let q = self.step(&q_prev, &q_prev2, c1, c2)?;
            *matvecs += q0.cols;
            e.axpy(a[r], &q);
            q_prev2 = q_prev;
            q_prev = q;
        }
        Ok(e)
    }
}

impl Operator for PjrtStepOp {
    fn dim(&self) -> usize {
        self.n
    }

    // PJRT owns its own device-side parallelism; the policy is ignored.
    fn apply_into(&self, x: &Mat, y: &mut Mat, _exec: &ExecPolicy) {
        let zero = Mat::zeros(x.rows, x.cols);
        let out = self
            .step(x, &zero, 1.0, 0.0)
            .expect("PJRT step execution failed");
        y.data.copy_from_slice(&out.data);
    }

    fn nnz(&self) -> usize {
        self.nnz
    }
}

/// Implicit Gaussian-kernel operator `K@Q` (kernel PCA, paper eq. (1)).
pub struct GaussKernelOp {
    rt: Arc<Runtime>,
    exe: Arc<xla::PjRtLoadedExecutable>,
    x_lit: xla::Literal,
    pub l: usize,
    pub feat: usize,
    pub d: usize,
    alpha: f32,
}

impl GaussKernelOp {
    pub fn new(rt: Arc<Runtime>, arts: &Artifacts, points: &Mat, alpha: f64) -> Result<GaussKernelOp> {
        let info = arts
            .find_prefix("gauss_matvec")
            .context("no gauss_matvec artifact in manifest")?;
        let (l, feat) = (info.params[0][0], info.params[0][1]);
        let d = info.params[1][1];
        anyhow::ensure!(
            points.rows == l && points.cols == feat,
            "point cloud is {}x{}, artifact baked for {l}x{feat}",
            points.rows,
            points.cols
        );
        let exe = rt.load_hlo_text(&info.file)?;
        let x_lit = literal_from_mat(points)?;
        Ok(GaussKernelOp { rt, exe, x_lit, l, feat, d, alpha: alpha as f32 })
    }
}

impl Operator for GaussKernelOp {
    fn dim(&self) -> usize {
        self.l
    }

    // PJRT owns its own device-side parallelism; the policy is ignored.
    fn apply_into(&self, x: &Mat, y: &mut Mat, _exec: &ExecPolicy) {
        assert_eq!(x.rows, self.l);
        assert_eq!(x.cols, self.d, "gauss artifact baked for d={}", self.d);
        let q = literal_from_mat(x).expect("literal");
        let alpha = literal_vec(&[self.alpha]);
        let out = self
            .rt
            .execute_tuple1(&self.exe, &[self.x_lit.clone(), q, alpha])
            .expect("PJRT gauss execution failed");
        let m = mat_from_literal(&out, self.l, self.d).expect("literal shape");
        y.data.copy_from_slice(&m.data);
    }

    fn nnz(&self) -> usize {
        self.l * self.l
    }
}

// PJRT integration tests live in rust/tests/pjrt_roundtrip.rs (they need
// built artifacts and a compiled client; unit tests here would force every
// `cargo test` invocation through XLA compilation).
