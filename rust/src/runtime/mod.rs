//! PJRT runtime: load the JAX/Pallas-authored HLO artifacts and execute
//! them from the Rust request path (python never runs at serve time).
//!
//! * [`artifacts`] — manifest-driven registry of `artifacts/*.hlo.txt`
//!   (written by `python/compile/aot.py`), with shape validation.
//! * [`client`] — PJRT CPU client + compiled-executable cache and the
//!   f32 Literal ⇄ [`crate::linalg::Mat`] plumbing.
//! * [`ops`] — the tile operators: [`ops::PjrtStepOp`] drives the fused
//!   Pallas recursion-step kernel (one compiled executable serves *any*
//!   polynomial order — Rust owns the loop), and [`ops::GaussKernelOp`]
//!   exposes the implicit Gaussian-kernel operator for kernel PCA.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 emits 64-bit instruction ids in
//! serialized protos which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and aot.py).

//!
//! The artifact registry is always available; the PJRT client and the
//! tile operators need the vendored `xla` + `anyhow` crate closure and
//! are gated behind the `pjrt` cargo feature (see Cargo.toml). Without
//! the feature this module still parses manifests and lists artifacts —
//! it just cannot execute them.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod ops;

pub use artifacts::Artifacts;
#[cfg(feature = "pjrt")]
pub use client::Runtime;
