//! PJRT CPU client wrapper + Literal ⇄ Mat plumbing + executable cache.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::linalg::Mat;

/// The PJRT runtime: one CPU client + a cache of compiled executables
/// keyed by artifact path. Compilation happens once per artifact per
/// process; execution is the request path.
pub struct Runtime {
    pub client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load_hlo_text(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = path.display().to_string();
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute with f32 literals; unwraps the 1-element result tuple that
    /// `return_tuple=True` lowering produces.
    pub fn execute_tuple1(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<xla::Literal> {
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }

    /// Execute and decompose an n-tuple result.
    pub fn execute_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Mat (f64, row-major) → f32 literal of shape (rows, cols).
pub fn literal_from_mat(m: &Mat) -> Result<xla::Literal> {
    let data: Vec<f32> = m.data.iter().map(|&x| x as f32).collect();
    Ok(xla::Literal::vec1(&data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// f32 vector literal of shape (len,).
pub fn literal_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 literal (rows, cols) → Mat (f64).
pub fn mat_from_literal(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v: Vec<f32> = lit.to_vec()?;
    anyhow::ensure!(
        v.len() == rows * cols,
        "literal has {} elements, expected {}x{}",
        v.len(),
        rows,
        cols
    );
    Ok(Mat::from_vec(rows, cols, v.into_iter().map(|x| x as f64).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_literal_roundtrip() {
        let m = Mat::from_rows(&[&[1.0, 2.5], &[-3.0, 0.25], &[0.0, 9.0]]);
        let lit = literal_from_mat(&m).unwrap();
        let back = mat_from_literal(&lit, 3, 2).unwrap();
        assert!(m.max_abs_diff(&back) < 1e-7);
    }

    #[test]
    fn mat_from_literal_shape_mismatch_errors() {
        let lit = xla::Literal::vec1(&[1f32, 2.0, 3.0]);
        assert!(mat_from_literal(&lit, 2, 2).is_err());
    }
}
