//! Artifact registry: parse `manifest.json`, validate shapes, locate HLO
//! text files.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One artifact's metadata from the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    /// Parameter shapes in order (e.g. [[256,256],[256,32],[256,32],[2]]).
    pub params: Vec<Vec<usize>>,
}

/// The registry of AOT artifacts in a directory.
pub struct Artifacts {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactInfo>,
    /// Tile geometry recorded by aot.py (`_tile` key), name → value.
    pub tile: std::collections::BTreeMap<String, usize>,
}

impl Artifacts {
    /// Load `dir/manifest.json`. Fails with a readable error when the
    /// artifacts have not been built (`make artifacts`).
    pub fn load(dir: &Path) -> Result<Artifacts, String> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            format!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let json = Json::parse(&text).map_err(|e| format!("bad manifest: {e}"))?;
        let mut entries = Vec::new();
        let mut tile = std::collections::BTreeMap::new();
        for key in json.keys() {
            if key == "_tile" {
                if let Json::Obj(m) = json.get(key).unwrap() {
                    for (k, v) in m {
                        if let Some(u) = v.as_usize() {
                            tile.insert(k.clone(), u);
                        }
                    }
                }
                continue;
            }
            if key.starts_with('_') {
                continue; // reference data blocks
            }
            let entry = json.get(key).unwrap();
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| format!("artifact {key}: missing file"))?;
            let params = entry
                .get("params")
                .and_then(|p| p.as_arr())
                .ok_or_else(|| format!("artifact {key}: missing params"))?
                .iter()
                .map(|shape| {
                    shape
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect()
                })
                .collect();
            entries.push(ArtifactInfo {
                name: key.clone(),
                file: dir.join(file),
                params,
            });
        }
        Ok(Artifacts { dir: dir.to_path_buf(), entries, tile })
    }

    /// Default location: `$CSE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("CSE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find an artifact by prefix (e.g. "legendre_step").
    pub fn find_prefix(&self, prefix: &str) -> Option<&ArtifactInfo> {
        self.entries.iter().find(|e| e.name.starts_with(prefix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("cse_artifacts_test");
        write_manifest(
            &dir,
            r#"{"step": {"file": "step.hlo.txt", "params": [[4,4],[4,2],[2]], "dtype": "f32"},
                "_tile": {"n": 4, "d": 2},
                "_ref": [1.0, 2.0]}"#,
        );
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.entries.len(), 1);
        let e = a.get("step").unwrap();
        assert_eq!(e.params, vec![vec![4, 4], vec![4, 2], vec![2]]);
        assert_eq!(a.tile["n"], 4);
        assert!(a.find_prefix("st").is_some());
        assert!(a.get("missing").is_none());
    }

    #[test]
    fn missing_manifest_is_actionable_error() {
        let dir = std::env::temp_dir().join("cse_artifacts_missing");
        let _ = std::fs::remove_dir_all(&dir);
        let err = match Artifacts::load(&dir) {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing manifest"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_manifest_if_present() {
        // When the repo's artifacts are built, validate the real manifest.
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let a = Artifacts::load(&dir).unwrap();
            assert!(a.find_prefix("legendre_step").is_some());
            assert!(a.find_prefix("gauss_matvec").is_some());
            for e in &a.entries {
                assert!(e.file.exists(), "missing {}", e.file.display());
            }
        }
    }
}
