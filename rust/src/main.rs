//! `cse` — command-line launcher for the compressive-spectral-embedding
//! system. Subcommands:
//!
//! ```text
//! cse gen-graph  --kind sbm --n 20000 --k 200 --deg-in 5 --deg-out 1.6 --out g.txt
//! cse embed      --graph g.txt --d 80 --order 180 --cascade 2 --out emb.tsv
//! cse eig        --graph g.txt --solver lanczos --k 100
//! cse cluster    --graph g.txt --kmeans-k 200 --d 80 --order 180
//! cse serve      --graph g.txt --queries 1000 --topk 10
//! cse artifacts  [--dir artifacts]
//! ```
//!
//! Run any subcommand with `--help` for the full option list. Every
//! subcommand also accepts `--stats` (per-stage latency histograms,
//! printed as an observability report at job end) and `--trace FILE`
//! (tracing spans exported as Chrome trace_event JSON).

use std::path::Path;

use cse::cluster::{kmeans, modularity, KmeansParams};
use cse::coordinator::{Coordinator, EmbedJob, QueryBatch, SimilarityService};
use cse::coordinator::service::Query;
use cse::eigen::lanczos::{lanczos, LanczosParams};
use cse::eigen::rsvd::{rsvd, RsvdParams};
use cse::eigen::simult::simultaneous_iteration;
use cse::embed::Params;
use cse::funcs::SpectralFn;
use cse::index::{evaluate_recall, AnnIndex, ExactIndex, SimHashIndex, SimHashParams};
use cse::par::ExecPolicy;
use cse::poly::Basis;
use cse::sparse::{gen, graph, io, tune, Csr, FormatChoice, KernelCfg, SparseMat};
use cse::util::args::{usage, Args, Opt};
use cse::util::rng::Rng;
use cse::util::timer::Timer;
use cse::util::{human_bytes, human_secs};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", top_usage());
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let result = match cmd.as_str() {
        "gen-graph" => cmd_gen_graph(argv),
        "embed" => cmd_embed(argv),
        "eig" => cmd_eig(argv),
        "cluster" => cmd_cluster(argv),
        "serve" => cmd_serve(argv),
        "artifacts" => cmd_artifacts(argv),
        "--help" | "help" => {
            println!("{}", top_usage());
            Ok(())
        }
        other => Err(format!("unknown subcommand '{other}'\n{}", top_usage())),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn top_usage() -> String {
    "cse — compressive spectral embedding (NIPS 2015 reproduction)\n\
     subcommands: gen-graph | embed | eig | cluster | serve | artifacts\n\
     run `cse <subcommand> --help` for options"
        .to_string()
}

/// Load a graph from `--graph FILE`, or generate per `--kind/--n/...`.
fn load_or_gen(a: &Args) -> Result<(Csr, Option<Vec<usize>>), String> {
    if let Some(path) = a.get("graph") {
        let (adj, _) = io::read_edge_list(Path::new(path)).map_err(|e| e.to_string())?;
        adj.validate().map_err(|e| format!("invalid graph in {path}: {e}"))?;
        eprintln!("loaded {}: n={} nnz={}", path, adj.rows, adj.nnz());
        return Ok((adj, None));
    }
    let mut rng = Rng::new(a.u64("seed", 0)?);
    let n = a.usize("n", 20_000)?;
    let kind = a.get_or("kind", "sbm");
    match kind {
        "sbm" => {
            let k = a.usize("k", 200)?;
            let g = gen::sbm_by_degree(
                &mut rng,
                n,
                k,
                a.f64("deg-in", 5.0)?,
                a.f64("deg-out", 1.6)?,
            );
            eprintln!("generated SBM: n={n} k={k} nnz={}", g.adj.nnz());
            Ok((g.adj, g.labels))
        }
        "er" => {
            let m = a.usize("m", n * 3)?;
            let g = gen::erdos_renyi(&mut rng, n, m);
            Ok((g.adj, None))
        }
        "ba" => {
            let m = a.usize("m", 3)?;
            let g = gen::barabasi_albert(&mut rng, n, m);
            Ok((g.adj, None))
        }
        other => Err(format!("unknown graph kind '{other}' (sbm|er|ba)")),
    }
}

/// `--threads N` → kernel-level ExecPolicy; 0 (the default) = all cores.
fn exec_from(a: &Args) -> Result<ExecPolicy, String> {
    let t = a.usize("threads", 0)?;
    Ok(if t == 0 { ExecPolicy::auto() } else { ExecPolicy::with_threads(t) })
}

/// Kernel policy for coordinator paths: an explicit `--threads` always
/// wins (including `--threads 1` = deliberately serial kernels); the
/// default `0` asks the scheduler to compose the kernel thread count
/// from the core budget (`cores / workers`, via `EmbedJob.auto_threads`).
fn coord_exec(a: &Args) -> Result<(ExecPolicy, bool), String> {
    let t = a.usize("threads", 0)?;
    Ok(if t > 0 {
        (ExecPolicy::with_threads(t), false)
    } else {
        (ExecPolicy::serial(), true)
    })
}

fn embed_params(a: &Args) -> Result<Params, String> {
    Ok(Params {
        d: a.usize("d", 0)?,
        order: a.usize("order", 120)?,
        cascade: a.usize("cascade", 2)?,
        basis: match a.get_or("basis", "legendre") {
            "legendre" => Basis::Legendre,
            "chebyshev" => Basis::Chebyshev,
            b => return Err(format!("unknown basis '{b}'")),
        },
        norm_est: None, // normalized adjacency: ||S|| <= 1 by construction
        exec: exec_from(a)?,
    })
}

/// Sparse-backend knobs shared by the iterating subcommands.
const FORMAT_OPTS: &[Opt] = &[
    Opt {
        name: "format",
        help: "sparse storage backend: csr|sell|auto (auto = SELL-C-sigma when the \
               degree distribution's coefficient of variation crosses 0.75); \
               every backend produces bitwise-identical results",
        default: Some("auto"),
    },
    Opt {
        name: "tune",
        help: "micro-benchmark kernel lane width x block budget x format on the \
               actual matrix before the job and run with the fastest point (flag; \
               cached per matrix shape for the process lifetime)",
        default: None,
    },
];

/// RHS-width hint for the autotuner: the block width the job will
/// actually iterate with (0 = the scheduler's `6 ln n` auto-pick).
fn tune_d_hint(d: usize, n: usize) -> usize {
    if d > 0 {
        d
    } else {
        (6.0 * (n.max(2) as f64).ln()).ceil() as usize
    }
}

/// Resolve `--format`/`--tune` into the sparse backend the job iterates.
/// `--tune` measures the actual matrix (cached per shape); its kernel
/// configuration always applies, but its format pick only overrides
/// `--format auto` — an explicit csr/sell request is honored.
fn build_operator(a: &Args, na: Csr, d_hint: usize) -> Result<SparseMat, String> {
    let mut choice = FormatChoice::parse(a.get_or("format", "auto"))?;
    let mut cfg = KernelCfg::default();
    if a.flag("tune") {
        let p = tune::tune(&na, d_hint);
        cfg = p.cfg;
        if choice == FormatChoice::Auto {
            choice = match p.format {
                tune::TunedFormat::Sell => FormatChoice::Sell,
                tune::TunedFormat::Csr => FormatChoice::Csr,
            };
        }
        let provenance = if p.cached {
            "cached".to_string()
        } else {
            format!("swept in {:.1} ms", p.tune_ms)
        };
        eprintln!(
            "autotune (d={}): csr {:.2} GFLOP/s, sell {:.2} GFLOP/s -> max_tile={} row_block_nnz={} ({provenance})",
            d_hint, p.csr_gflops, p.sell_gflops, p.cfg.max_tile, p.cfg.row_block_nnz
        );
    }
    let op = SparseMat::build(na, choice, cfg).map_err(|e| e.to_string())?;
    eprintln!("sparse backend: {} ({})", op.format_name(), human_bytes(op.mem_bytes()));
    Ok(op)
}

const THREADS_OPT: Opt = Opt {
    name: "threads",
    help: "kernel threads per block product (0 = auto: all cores, or cores/workers \
           under the coordinator); deterministic at any value",
    default: Some("0"),
};

/// Memory-locality knobs shared by the iterating subcommands. Both are
/// pure performance policy: neither can change a single output bit.
const LOCALITY_OPTS: &[Opt] = &[
    Opt {
        name: "numa",
        help: "NUMA first-touch placement of the operator arrays: auto (place when \
               more than one node is detected) | off",
        default: Some("auto"),
    },
    Opt {
        name: "pin",
        help: "pin pool workers to node-local core sets (flag; needs a build with \
               the `affinity` feature on Linux, no-op otherwise)",
        default: None,
    },
];

/// Apply `--pin`: a runtime opt-in the lazily-spawned pool workers see
/// at spawn time, so this must run before the first parallel region.
fn locality_setup(a: &Args) {
    if a.flag("pin") {
        cse::par::affinity::set_pinning(true);
        if cse::par::affinity::can_pin() {
            let topo = cse::par::topo::detect();
            eprintln!(
                "pinning pool workers round-robin across {} NUMA node(s)",
                topo.num_nodes()
            );
        } else {
            eprintln!(
                "--pin requested but this build cannot pin (needs the `affinity` \
                 cargo feature on Linux x86_64/aarch64); continuing unpinned"
            );
        }
    }
}

/// Apply `--numa auto|off` to the built operator: first-touch placement
/// of its index/value arrays when more than one node is detected
/// (single-node hosts skip it — nothing to place).
fn apply_numa(a: &Args, op: &mut SparseMat) -> Result<(), String> {
    match a.get_or("numa", "auto") {
        "off" => Ok(()),
        "auto" => {
            let topo = cse::par::topo::detect();
            if topo.num_nodes() > 1 {
                let exec = ExecPolicy::with_threads(topo.physical_cores());
                op.place(&exec);
                eprintln!(
                    "numa: first-touch placed operator arrays across {} nodes",
                    topo.num_nodes()
                );
            }
            Ok(())
        }
        other => Err(format!("--numa: expected auto|off, got '{other}'")),
    }
}

/// Robustness knobs shared by the coordinator-driven subcommands.
const FAULT_OPTS: &[Opt] = &[
    Opt {
        name: "fault-spec",
        help: "arm deterministic fault injection: comma-separated site:kind[:p=P][:seed=N][:ms=N] \
               with kinds panic|delay|poison and sites shard_run|pool_task (or env CSE_FAULT_SPEC)",
        default: None,
    },
    Opt {
        name: "max-retries",
        help: "shard re-executions after a caught panic/blow-up before the job fails",
        default: Some("8"),
    },
    Opt {
        name: "deadline-ms",
        help: "embedding-job deadline in milliseconds (0 = no deadline)",
        default: Some("0"),
    },
    Opt {
        name: "retry-backoff-ms",
        help: "base delay for jittered exponential backoff between shard retries \
               (0 = retry immediately); the jitter is a pure hash of (shard, attempt), \
               so retry timing is deterministic under --fault-spec seeds",
        default: Some("0"),
    },
];

/// Arm the fault-injection registry from `--fault-spec` or the
/// `CSE_FAULT_SPEC` environment variable (flag wins). No-op when
/// neither is set — the disarmed fast path costs one atomic load.
fn fault_setup(a: &Args) -> Result<(), String> {
    let spec = a
        .get("fault-spec")
        .map(str::to_string)
        .or_else(|| std::env::var(cse::fault::ENV_SPEC).ok().filter(|s| !s.is_empty()));
    if let Some(spec) = spec {
        cse::fault::arm(&spec)?;
        eprintln!("fault injection armed: {spec}");
    }
    Ok(())
}

/// Apply `--max-retries` / `--deadline-ms` to an [`EmbedJob`].
fn job_robustness(a: &Args, job: &mut EmbedJob) -> Result<(), String> {
    job.max_retries = a.usize("max-retries", cse::coordinator::scheduler::DEFAULT_MAX_RETRIES)?;
    job.deadline_ms = match a.u64("deadline-ms", 0)? {
        0 => None,
        ms => Some(ms),
    };
    job.retry_backoff_ms = a.u64("retry-backoff-ms", 0)?;
    Ok(())
}

/// Post-job line making silent recoveries visible on the console.
fn report_retries(retries: usize) {
    if retries > 0 {
        println!("recovered from {retries} shard failure(s) via retry");
    }
}

const OBS_OPTS: &[Opt] = &[
    Opt {
        name: "stats",
        help: "collect per-stage latency histograms and print an observability report (flag)",
        default: None,
    },
    Opt {
        name: "trace",
        help: "write spans as Chrome trace_event JSON to FILE (open in chrome://tracing or \
               ui.perfetto.dev); implies --stats",
        default: None,
    },
];

/// Enable observability per `--stats` / `--trace FILE`; returns the
/// trace output path (tracing implies stats).
fn obs_setup(a: &Args) -> Option<String> {
    if a.flag("stats") {
        cse::obs::set_stats(true);
    }
    let trace = a.get("trace").map(str::to_string);
    if trace.is_some() {
        cse::obs::set_tracing(true);
    }
    trace
}

/// At job end: write the trace file and print the per-stage report.
fn obs_finish(trace: Option<String>) -> Result<(), String> {
    if let Some(path) = trace {
        let t = cse::obs::drain_trace();
        std::fs::write(&path, t.to_chrome_json().to_string())
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}: {} spans ({} dropped)", t.events.len(), t.dropped);
        print!("{}", t.summary());
    }
    if cse::obs::stats_enabled() {
        print!("{}", cse::obs::ObsReport::capture().render());
    }
    Ok(())
}

const COMMON_OPTS: &[Opt] = &[
    Opt { name: "graph", help: "edge-list file (SNAP format); omit to generate", default: None },
    Opt { name: "kind", help: "generator when no --graph: sbm|er|ba", default: Some("sbm") },
    Opt { name: "n", help: "generated graph size", default: Some("20000") },
    Opt { name: "k", help: "SBM community count", default: Some("200") },
    Opt { name: "deg-in", help: "SBM within-community degree", default: Some("5.0") },
    Opt { name: "deg-out", help: "SBM between-community degree", default: Some("1.6") },
    Opt { name: "seed", help: "RNG seed", default: Some("0") },
];

fn cmd_gen_graph(argv: Vec<String>) -> Result<(), String> {
    let a = Args::parse(argv, &["help", "stats"])?;
    if a.flag("help") {
        let mut opts = COMMON_OPTS.to_vec();
        opts.extend_from_slice(OBS_OPTS);
        println!(
            "{}",
            usage("cse gen-graph", "Generate a synthetic graph and write an edge list", &opts)
        );
        return Ok(());
    }
    let trace = obs_setup(&a);
    let (adj, labels) = load_or_gen(&a)?;
    let out = a.get_or("out", "graph.txt");
    io::write_edge_list(Path::new(out), &adj, "generated by cse gen-graph")
        .map_err(|e| e.to_string())?;
    println!("wrote {out}: n={} edges={} ({})", adj.rows, adj.nnz() / 2, human_bytes(adj.mem_bytes()));
    if let Some(l) = labels {
        let lab_out = format!("{out}.labels");
        let rows: Vec<Vec<f64>> = l.iter().map(|&x| vec![x as f64]).collect();
        io::write_tsv(Path::new(&lab_out), &["label"], &rows).map_err(|e| e.to_string())?;
        println!("wrote {lab_out}");
    }
    obs_finish(trace)
}

fn cmd_embed(argv: Vec<String>) -> Result<(), String> {
    let a = Args::parse(argv, &["help", "stats", "tune", "pin"])?;
    if a.flag("help") {
        let mut opts = COMMON_OPTS.to_vec();
        opts.extend_from_slice(&[
            Opt { name: "d", help: "embedding dimension (0 = 6 log n)", default: Some("0") },
            Opt { name: "order", help: "polynomial order L (matvec budget)", default: Some("120") },
            Opt { name: "cascade", help: "cascade factor b", default: Some("2") },
            Opt { name: "basis", help: "legendre|chebyshev", default: Some("legendre") },
            Opt { name: "c", help: "step threshold f = I(lambda >= c)", default: Some("0.7") },
            Opt {
                name: "workers",
                help: "column-shard worker threads (0 = auto-compose workers x threads from cores)",
                default: Some("0"),
            },
            THREADS_OPT,
            Opt {
                name: "shard",
                help: "columns per shard (0 = adaptive from n, d and cache budget)",
                default: Some("0"),
            },
            Opt { name: "out", help: "embedding TSV output", default: Some("embedding.tsv") },
        ]);
        opts.extend_from_slice(FORMAT_OPTS);
        opts.extend_from_slice(LOCALITY_OPTS);
        opts.extend_from_slice(FAULT_OPTS);
        opts.extend_from_slice(OBS_OPTS);
        println!("{}", usage("cse embed", "Compressive spectral embedding of a graph", &opts));
        return Ok(());
    }
    let trace = obs_setup(&a);
    fault_setup(&a)?;
    locality_setup(&a);
    let (adj, _) = load_or_gen(&a)?;
    let na = graph::normalized_adjacency(&adj);
    let n = na.rows;
    let mut op = build_operator(&a, na, tune_d_hint(a.usize("d", 0)?, n))?;
    apply_numa(&a, &mut op)?;
    let workers = a.usize("workers", 0)?;
    let mut params = embed_params(&a)?;
    let (exec, auto_threads) = coord_exec(&a)?;
    params.exec = exec;
    let f = SpectralFn::Step { c: a.f64("c", 0.7)? };
    let mut job = EmbedJob::new(params, f, a.u64("seed", 0)?);
    job.shard_width = a.usize("shard", 0)?;
    job.auto_threads = auto_threads;
    job_robustness(&a, &mut job)?;
    let coord = Coordinator::new(workers);
    let t = Timer::start();
    let res = coord.run(&op, &job).map_err(|e| e.to_string())?;
    let secs = t.elapsed_secs();
    println!(
        "embedded n={} into d={} (order={}, b={}, {} matvecs, {} shards, {} workers x {} kernel threads) in {}",
        n,
        res.e.cols,
        job.params.order,
        res.plan.b,
        res.matvecs,
        res.shards,
        res.workers,
        res.threads,
        human_secs(secs)
    );
    report_retries(res.retries);
    let out = a.get_or("out", "embedding.tsv");
    let rows: Vec<Vec<f64>> = (0..res.e.rows).map(|i| res.e.row(i).to_vec()).collect();
    let header: Vec<String> = (0..res.e.cols).map(|j| format!("e{j}")).collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    io::write_tsv(Path::new(out), &header_refs, &rows).map_err(|e| e.to_string())?;
    println!("wrote {out}");
    obs_finish(trace)
}

fn cmd_eig(argv: Vec<String>) -> Result<(), String> {
    let a = Args::parse(argv, &["help", "stats", "tune", "pin"])?;
    if a.flag("help") {
        let mut opts = COMMON_OPTS.to_vec();
        opts.extend_from_slice(&[
            Opt { name: "solver", help: "lanczos|rsvd|simult", default: Some("lanczos") },
            Opt { name: "eig-k", help: "number of eigenpairs", default: Some("50") },
            THREADS_OPT,
        ]);
        opts.extend_from_slice(FORMAT_OPTS);
        opts.extend_from_slice(LOCALITY_OPTS);
        opts.extend_from_slice(OBS_OPTS);
        println!("{}", usage("cse eig", "Partial eigendecomposition baselines", &opts));
        return Ok(());
    }
    let trace = obs_setup(&a);
    locality_setup(&a);
    let (adj, _) = load_or_gen(&a)?;
    let na = graph::normalized_adjacency(&adj);
    let k = a.usize("eig-k", 50)?;
    let mut op = build_operator(&a, na, k)?;
    apply_numa(&a, &mut op)?;
    let exec = exec_from(&a)?;
    let mut rng = Rng::new(a.u64("seed", 0)?);
    let t = Timer::start();
    let pe = match a.get_or("solver", "lanczos") {
        "lanczos" => lanczos(&op, k, &LanczosParams { exec, ..Default::default() }, &mut rng),
        "rsvd" => rsvd(&op, k, &RsvdParams { exec, ..Default::default() }, &mut rng),
        "simult" => simultaneous_iteration(&op, k, 100, &mut rng, &exec),
        s => return Err(format!("unknown solver '{s}'")),
    };
    println!(
        "{} eigenpairs in {} ({} matvecs)",
        pe.values.len(),
        human_secs(t.elapsed_secs()),
        pe.matvecs
    );
    for (i, v) in pe.values.iter().enumerate().take(10) {
        println!("  lambda[{i}] = {v:.6}");
    }
    if pe.values.len() > 10 {
        println!("  ... lambda[{}] = {:.6}", pe.values.len() - 1, pe.values.last().unwrap());
    }
    obs_finish(trace)
}

fn cmd_cluster(argv: Vec<String>) -> Result<(), String> {
    let a = Args::parse(argv, &["help", "stats", "tune", "pin"])?;
    if a.flag("help") {
        let mut opts = COMMON_OPTS.to_vec();
        opts.extend_from_slice(&[
            Opt { name: "kmeans-k", help: "number of clusters K", default: Some("200") },
            Opt { name: "d", help: "embedding dimension", default: Some("80") },
            Opt { name: "order", help: "polynomial order", default: Some("120") },
            Opt { name: "c", help: "step threshold", default: Some("0.7") },
            Opt { name: "restarts", help: "k-means restarts (median reported)", default: Some("5") },
            Opt {
                name: "workers",
                help: "column-shard worker threads (0 = auto-compose workers x threads from cores)",
                default: Some("0"),
            },
            THREADS_OPT,
        ]);
        opts.extend_from_slice(FORMAT_OPTS);
        opts.extend_from_slice(LOCALITY_OPTS);
        opts.extend_from_slice(FAULT_OPTS);
        opts.extend_from_slice(OBS_OPTS);
        println!("{}", usage("cse cluster", "Embed + K-means + modularity", &opts));
        return Ok(());
    }
    let trace = obs_setup(&a);
    fault_setup(&a)?;
    locality_setup(&a);
    let (adj, labels) = load_or_gen(&a)?;
    let na = graph::normalized_adjacency(&adj);
    let n = na.rows;
    let mut op = build_operator(&a, na, tune_d_hint(a.usize("d", 80)?, n))?;
    apply_numa(&a, &mut op)?;
    let workers = a.usize("workers", 0)?;
    let mut params = Params { d: a.usize("d", 80)?, ..embed_params(&a)? };
    let (exec, auto_threads) = coord_exec(&a)?;
    params.exec = exec;
    let f = SpectralFn::Step { c: a.f64("c", 0.7)? };
    let mut job = EmbedJob::new(params, f, a.u64("seed", 0)?);
    job.auto_threads = auto_threads;
    job_robustness(&a, &mut job)?;
    let coord = Coordinator::new(workers);
    let t = Timer::start();
    let res = coord.run(&op, &job).map_err(|e| e.to_string())?;
    println!("embedding: {}", human_secs(t.elapsed_secs()));
    report_retries(res.retries);
    let kk = a.usize("kmeans-k", 200)?;
    let restarts = a.usize("restarts", 5)?;
    let mut rng = Rng::new(a.u64("seed", 0)? + 1);
    let mut mods = Vec::new();
    for r in 0..restarts {
        let km = kmeans(
            &res.e,
            &KmeansParams { k: kk, max_iters: 30, tol: 1e-5, exec: exec_from(&a)? },
            &mut rng,
        );
        let q = modularity(&adj, &km.assignment);
        println!("  restart {r}: modularity = {q:.4} (cost {:.2}, {} iters)", km.cost, km.iters);
        mods.push(q);
        if let Some(ref l) = labels {
            println!("    nmi vs planted = {:.4}", cse::cluster::nmi(&km.assignment, l));
        }
    }
    println!("median modularity = {:.4}", cse::util::stats::median(&mods));
    obs_finish(trace)
}

fn cmd_serve(argv: Vec<String>) -> Result<(), String> {
    let a = Args::parse(argv, &["help", "stats", "tune", "pin"])?;
    if a.flag("help") {
        let mut opts = COMMON_OPTS.to_vec();
        opts.extend_from_slice(&[
            Opt { name: "queries", help: "number of random queries", default: Some("1000") },
            Opt { name: "topk", help: "k for top-k queries", default: Some("10") },
            Opt {
                name: "workers",
                help: "service worker threads (also the embed shard pool; 0 = auto-compose)",
                default: Some("2"),
            },
            Opt { name: "index", help: "top-k index: none|exact|simhash", default: Some("none") },
            Opt { name: "tables", help: "simhash: hash tables", default: Some("8") },
            Opt { name: "bits", help: "simhash: signature bits per table", default: Some("12") },
            Opt { name: "probes", help: "simhash: buckets probed per table", default: Some("16") },
            Opt {
                name: "recall-queries",
                help: "sampled top-k queries for the recall@k report (0 = skip)",
                default: Some("50"),
            },
            Opt {
                name: "shed-p99-us",
                help: "shed top-k queries once latency p99 exceeds this many µs (0 = off)",
                default: Some("0"),
            },
            THREADS_OPT,
        ]);
        opts.extend_from_slice(FORMAT_OPTS);
        opts.extend_from_slice(LOCALITY_OPTS);
        opts.extend_from_slice(FAULT_OPTS);
        opts.extend_from_slice(OBS_OPTS);
        println!("{}", usage("cse serve", "Similarity-query service demo", &opts));
        return Ok(());
    }
    let trace = obs_setup(&a);
    fault_setup(&a)?;
    locality_setup(&a);
    let (adj, _) = load_or_gen(&a)?;
    let na = graph::normalized_adjacency(&adj);
    let n = na.rows;
    let mut op = build_operator(&a, na, tune_d_hint(a.usize("d", 0)?, n))?;
    apply_numa(&a, &mut op)?;
    let workers = a.usize("workers", 2)?;
    // Query-phase worker pool: `0` auto-sizes to the core count (the
    // coordinator separately auto-composes its own shard split).
    let qworkers = if workers == 0 {
        std::thread::available_parallelism().map_or(1, |c| c.get())
    } else {
        workers
    };
    let mut params = embed_params(&a)?;
    let (exec, auto_threads) = coord_exec(&a)?;
    params.exec = exec;
    let f = SpectralFn::Step { c: a.f64("c", 0.7)? };
    let mut job = EmbedJob::new(params, f, a.u64("seed", 0)?);
    job.auto_threads = auto_threads;
    job_robustness(&a, &mut job)?;
    let res = Coordinator::new(workers).run(&op, &job).map_err(|e| e.to_string())?;
    report_retries(res.retries);
    let mut service = SimilarityService::new(res.e);
    let shed = a.f64("shed-p99-us", 0.0)?;
    if shed > 0.0 {
        service.set_shed_threshold(Some(shed));
        println!("load shedding armed: top-k rejected above p99 {shed} µs");
    }

    // Optional ANN index over the embedding rows, with a build report.
    let defaults = SimHashParams::default();
    match a.get_or("index", "none") {
        "none" => {}
        "exact" => {
            service.attach_index(Box::new(ExactIndex::new(service.len())));
            println!("index: exact scan behind the AnnIndex trait (baseline)");
        }
        "simhash" => {
            let p = SimHashParams {
                tables: a.usize("tables", defaults.tables)?,
                bits: a.usize("bits", defaults.bits)?,
                probes: a.usize("probes", defaults.probes)?,
                seed: a.u64("seed", 0)? ^ defaults.seed,
                exec: exec_from(&a)?,
            };
            let idx = SimHashIndex::build(service.embedding(), p);
            println!(
                "index: simhash tables={} bits={} probes={} — built in {} ({})",
                p.tables,
                p.bits,
                p.probes,
                human_secs(idx.build_secs),
                human_bytes(idx.mem_bytes())
            );
            service.attach_index(Box::new(idx));
        }
        other => return Err(format!("unknown index '{other}' (none|exact|simhash)")),
    }

    let nq = a.usize("queries", 1000)?;
    let topk = a.usize("topk", 10)?;
    let mut rng = Rng::new(a.u64("seed", 0)? + 7);
    let queries: Vec<Query> = (0..nq)
        .map(|t| {
            if t % 4 == 0 {
                Query::TopK { i: rng.below(service.len()), k: topk }
            } else {
                Query::Corr { i: rng.below(service.len()), j: rng.below(service.len()) }
            }
        })
        .collect();
    let t = Timer::start();
    let answers = QueryBatch::run(&service, &queries, qworkers);
    let secs = t.elapsed_secs();
    println!(
        "{} queries in {} ({:.0} qps)",
        answers.len(),
        human_secs(secs),
        answers.len() as f64 / secs,
    );
    // Percentiles come from the metrics histogram (exact on its
    // log-bucket grid); the mean rides along for comparability.
    println!(
        "latency: p50 {:.1} µs, p99 {:.1} µs, max {:.1} µs (mean {:.1} µs)",
        service.metrics.query_percentile_us(50.0),
        service.metrics.query_percentile_us(99.0),
        service.metrics.query_hist.max() as f64 / 1e3,
        service.metrics.mean_query_us()
    );
    let snap = service.metrics.snapshot();
    if snap.queries_shed > 0 || snap.fallback_exact > 0 {
        println!(
            "robustness: {} queries shed, {} exact-scan fallbacks",
            snap.queries_shed, snap.fallback_exact
        );
    }
    if snap.topk_queries > 0 {
        println!(
            "top-k: {} queries, mean candidate set {:.1} rows ({:.2}% of n={})",
            snap.topk_queries,
            service.metrics.mean_candidates(),
            100.0 * service.metrics.mean_candidates() / service.len().max(1) as f64,
            service.len()
        );
    }

    // Recall@k report: indexed answers against the exact scan.
    let rq = a.usize("recall-queries", 50)?;
    if rq > 0 && service.index_name().is_some() && !service.is_empty() {
        let sample: Vec<usize> = (0..rq).map(|_| rng.below(service.len())).collect();
        let idx = service.detach_index().unwrap();
        let rep = evaluate_recall(service.embedding(), service.norms(), idx.as_ref(), &sample, topk);
        println!(
            "recall@{}: mean {:.3}, min {:.3} over {} queries ({:.1} candidates/query, {:.2}% of rows)",
            rep.k,
            rep.mean_recall,
            rep.min_recall,
            rep.queries,
            rep.mean_candidates,
            100.0 * rep.candidate_fraction
        );
        service.attach_index(idx);
    }
    obs_finish(trace)
}

fn cmd_artifacts(argv: Vec<String>) -> Result<(), String> {
    let a = Args::parse(argv, &["help", "stats"])?;
    if a.flag("help") {
        println!("cse artifacts [--dir artifacts] — list AOT artifacts");
        return Ok(());
    }
    let trace = obs_setup(&a);
    let dir = a.get_or("dir", "artifacts");
    let arts = cse::runtime::Artifacts::load(Path::new(dir))?;
    println!("{} artifacts in {dir}:", arts.entries.len());
    for e in &arts.entries {
        let shapes: Vec<String> = e
            .params
            .iter()
            .map(|s| format!("[{}]", s.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")))
            .collect();
        println!("  {:<40} params: {}", e.name, shapes.join(" "));
    }
    println!("tile geometry: {:?}", arts.tile);
    obs_finish(trace)
}
