//! Synthetic workload generators.
//!
//! These stand in for the SNAP datasets used in the paper (no network
//! access in this environment — see DESIGN.md §3): the stochastic block
//! model reproduces the *spectral shape* the experiments depend on (a
//! cluster of k leading eigenvalues near 1 carrying community structure,
//! a bulk near 0), with planted ground-truth communities for the
//! clustering experiment.

use super::coo::Coo;
use super::csr::Csr;
use crate::util::rng::Rng;

/// A generated graph: adjacency + optional planted community labels.
pub struct GenGraph {
    pub adj: Csr,
    pub labels: Option<Vec<usize>>,
}

/// Stochastic block model with `k` equal-size blocks over `n` vertices.
/// `p_in`/`p_out` are within/between-block edge probabilities. Uses
/// Poisson-approximate pair sampling, O(expected edges), so n in the
/// hundreds of thousands is fine.
pub fn sbm(rng: &mut Rng, n: usize, k: usize, p_in: f64, p_out: f64) -> GenGraph {
    assert!(k >= 1 && n >= k);
    let labels: Vec<usize> = (0..n).map(|i| i * k / n).collect();
    // Block boundaries for uniform sampling within a block.
    let block_start: Vec<usize> = (0..k).map(|b| (b * n + k - 1) / k).collect();
    let block_end: Vec<usize> = (0..k).map(|b| ((b + 1) * n + k - 1) / k).collect();
    // Approximation: block b spans [b*n/k, (b+1)*n/k). Recompute exactly:
    let mut start = vec![n; k];
    let mut end = vec![0; k];
    for (i, &b) in labels.iter().enumerate() {
        start[b] = start[b].min(i);
        end[b] = end[b].max(i + 1);
    }
    let _ = (block_start, block_end);

    let mut seen = std::collections::HashSet::new();
    let mut edges: Vec<(usize, usize)> = Vec::new();

    // Within-block edges: per block, expected p_in * C(size, 2).
    for b in 0..k {
        let size = end[b] - start[b];
        if size < 2 {
            continue;
        }
        let pairs = (size * (size - 1) / 2) as f64;
        let target = poisson(rng, p_in * pairs);
        let mut placed = 0;
        let mut attempts = 0;
        while placed < target && attempts < 20 * target.max(8) {
            attempts += 1;
            let u = start[b] + rng.below(size);
            let v = start[b] + rng.below(size);
            if u == v {
                continue;
            }
            let key = (u.min(v), u.max(v));
            if seen.insert(key) {
                edges.push(key);
                placed += 1;
            }
        }
    }
    // Between-block edges: expected p_out * (C(n,2) - sum C(size,2)).
    let total_pairs = (n * (n - 1) / 2) as f64;
    let within_pairs: f64 = (0..k)
        .map(|b| {
            let s = end[b] - start[b];
            (s * (s - 1) / 2) as f64
        })
        .sum();
    let target = poisson(rng, p_out * (total_pairs - within_pairs));
    let mut placed = 0;
    let mut attempts = 0;
    while placed < target && attempts < 40 * target.max(8) {
        attempts += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v || labels[u] == labels[v] {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edges.push(key);
            placed += 1;
        }
    }

    GenGraph {
        adj: Csr::from_coo(&Coo::from_undirected_edges(n, &edges)),
        labels: Some(labels),
    }
}

/// Convenience: SBM calibrated by average degrees instead of probabilities.
/// `deg_in`: expected within-community degree, `deg_out`: expected
/// between-community degree per vertex.
pub fn sbm_by_degree(rng: &mut Rng, n: usize, k: usize, deg_in: f64, deg_out: f64) -> GenGraph {
    let size = n as f64 / k as f64;
    let p_in = (deg_in / (size - 1.0).max(1.0)).min(1.0);
    let p_out = if n as f64 - size > 0.0 {
        deg_out / (n as f64 - size)
    } else {
        0.0
    };
    sbm(rng, n, k, p_in, p_out)
}

/// Heterogeneous SBM: per-block within-community degree interpolated
/// linearly from `deg_in_min` (block 0) to `deg_in_max` (block k-1).
///
/// Real networks (the paper's DBLP/Amazon) have communities of widely
/// varying density, so their structural eigenvalues *spread* over a band
/// instead of clustering at one value — exactly the regime where
/// truncating to the top-d eigenvectors loses the weak communities while
/// a compressive embedding of the whole band keeps them (§5's clustering
/// result). Homogeneous SBMs cannot show that effect.
pub fn sbm_hetero(
    rng: &mut Rng,
    n: usize,
    k: usize,
    deg_in_min: f64,
    deg_in_max: f64,
    deg_out: f64,
) -> GenGraph {
    assert!(k >= 1 && n >= k && deg_in_max >= deg_in_min);
    let labels: Vec<usize> = (0..n).map(|i| i * k / n).collect();
    let mut start = vec![n; k];
    let mut end = vec![0; k];
    for (i, &b) in labels.iter().enumerate() {
        start[b] = start[b].min(i);
        end[b] = end[b].max(i + 1);
    }
    let mut seen = std::collections::HashSet::new();
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for b in 0..k {
        let size = end[b] - start[b];
        if size < 2 {
            continue;
        }
        let frac = if k > 1 { b as f64 / (k - 1) as f64 } else { 0.0 };
        let deg_in = deg_in_min + frac * (deg_in_max - deg_in_min);
        let p_in = (deg_in / (size as f64 - 1.0)).min(1.0);
        let pairs = (size * (size - 1) / 2) as f64;
        let target = poisson(rng, p_in * pairs);
        let mut placed = 0;
        let mut attempts = 0;
        while placed < target && attempts < 20 * target.max(8) {
            attempts += 1;
            let u = start[b] + rng.below(size);
            let v = start[b] + rng.below(size);
            if u == v {
                continue;
            }
            let key = (u.min(v), u.max(v));
            if seen.insert(key) {
                edges.push(key);
                placed += 1;
            }
        }
    }
    // Cross edges: expected deg_out per vertex.
    let target = poisson(rng, deg_out * n as f64 / 2.0);
    let mut placed = 0;
    let mut attempts = 0;
    while placed < target && attempts < 40 * target.max(8) {
        attempts += 1;
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v || labels[u] == labels[v] {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edges.push(key);
            placed += 1;
        }
    }
    GenGraph {
        adj: Csr::from_coo(&Coo::from_undirected_edges(n, &edges)),
        labels: Some(labels),
    }
}

/// Erdős–Rényi G(n, m): exactly `m` distinct edges.
pub fn erdos_renyi(rng: &mut Rng, n: usize, m: usize) -> GenGraph {
    let max_edges = n * (n - 1) / 2;
    let m = m.min(max_edges);
    let mut seen = std::collections::HashSet::with_capacity(m);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let u = rng.below(n);
        let v = rng.below(n);
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if seen.insert(key) {
            edges.push(key);
        }
    }
    GenGraph {
        adj: Csr::from_coo(&Coo::from_undirected_edges(n, &edges)),
        labels: None,
    }
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `m` existing vertices with probability proportional to degree.
/// Produces the heavy-tailed degree distribution of real co-purchase /
/// collaboration networks.
pub fn barabasi_albert(rng: &mut Rng, n: usize, m: usize) -> GenGraph {
    assert!(m >= 1 && n > m);
    let mut targets: Vec<usize> = (0..m).collect();
    let mut repeated: Vec<usize> = Vec::with_capacity(2 * n * m);
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n * m);
    for v in m..n {
        let mut chosen = std::collections::HashSet::new();
        for &t in &targets {
            if chosen.insert(t) {
                edges.push((t.min(v), t.max(v)));
            }
        }
        for &t in &chosen {
            repeated.push(t);
            repeated.push(v);
        }
        // Next targets: preferential attachment via the repeated list.
        targets = (0..m)
            .map(|_| {
                if repeated.is_empty() {
                    rng.below(v)
                } else {
                    repeated[rng.below(repeated.len())]
                }
            })
            .collect();
    }
    GenGraph {
        adj: Csr::from_coo(&Coo::from_undirected_edges(n, &edges)),
        labels: None,
    }
}

/// k-NN graph over a point cloud (rows of `points`, row-major, dim `dim`):
/// symmetrized union of each point's k nearest neighbours. Brute force
/// O(n^2 dim) — used for kernel-PCA-style workloads at modest n.
pub fn knn_graph(points: &[f64], n: usize, dim: usize, k: usize) -> Csr {
    assert_eq!(points.len(), n * dim);
    assert!(k < n);
    let mut coo = Coo::new(n, n);
    let mut seen = std::collections::HashSet::new();
    for i in 0..n {
        let pi = &points[i * dim..(i + 1) * dim];
        let mut dists: Vec<(f64, usize)> = (0..n)
            .filter(|&j| j != i)
            .map(|j| {
                let pj = &points[j * dim..(j + 1) * dim];
                let d2: f64 = pi.iter().zip(pj).map(|(a, b)| (a - b) * (a - b)).sum();
                (d2, j)
            })
            .collect();
        dists.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for &(_, j) in dists.iter().take(k) {
            let key = (i.min(j), i.max(j));
            if seen.insert(key) {
                coo.push_sym(key.0, key.1, 1.0);
            }
        }
    }
    Csr::from_coo(&coo)
}

/// Gaussian-mixture point cloud: `k` isotropic clusters in `dim`
/// dimensions, separation `sep`, unit within-cluster std.
/// Returns (points row-major, labels).
pub fn gaussian_mixture(rng: &mut Rng, n: usize, dim: usize, k: usize, sep: f64) -> (Vec<f64>, Vec<usize>) {
    let mut centers = vec![0.0; k * dim];
    for c in centers.iter_mut() {
        *c = rng.normal() * sep;
    }
    let mut pts = vec![0.0; n * dim];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = i * k / n;
        labels[i] = c;
        for t in 0..dim {
            pts[i * dim + t] = centers[c * dim + t] + rng.normal();
        }
    }
    (pts, labels)
}

/// Poisson sample via inversion (small mean) or normal approx (large mean).
fn poisson(rng: &mut Rng, mean: f64) -> usize {
    if mean <= 0.0 {
        return 0;
    }
    if mean > 64.0 {
        let x = mean + mean.sqrt() * rng.normal();
        return x.max(0.0).round() as usize;
    }
    let l = (-mean).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
        if k > 10_000 {
            return k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graph::connected_components;

    #[test]
    fn sbm_has_planted_structure() {
        let mut rng = Rng::new(51);
        let g = sbm(&mut rng, 400, 4, 0.2, 0.002);
        let labels = g.labels.as_ref().unwrap();
        // Count within vs between edges.
        let (mut within, mut between) = (0usize, 0usize);
        for i in 0..g.adj.rows {
            let (idx, _) = g.adj.row(i);
            for &j in idx {
                if labels[i] == labels[j as usize] {
                    within += 1;
                } else {
                    between += 1;
                }
            }
        }
        assert!(within > 8 * between, "within {within} between {between}");
    }

    #[test]
    fn sbm_by_degree_calibrates() {
        let mut rng = Rng::new(52);
        let g = sbm_by_degree(&mut rng, 2000, 20, 5.0, 1.0);
        let avg_deg = g.adj.nnz() as f64 / g.adj.rows as f64;
        assert!((avg_deg - 6.0).abs() < 1.0, "avg degree {avg_deg}");
    }

    #[test]
    fn sbm_hetero_density_gradient() {
        let mut rng = Rng::new(58);
        let g = sbm_hetero(&mut rng, 1200, 12, 4.0, 20.0, 0.5);
        let labels = g.labels.as_ref().unwrap();
        // Within-degree of first block << last block.
        let block_deg = |b: usize| -> f64 {
            let idx: Vec<usize> = (0..1200).filter(|&i| labels[i] == b).collect();
            let mut within = 0.0;
            for &i in &idx {
                let (cols, _) = g.adj.row(i);
                within += cols.iter().filter(|&&j| labels[j as usize] == b).count() as f64;
            }
            within / idx.len() as f64
        };
        let d0 = block_deg(0);
        let d11 = block_deg(11);
        assert!(d11 > 3.0 * d0, "gradient missing: {d0} vs {d11}");
    }

    #[test]
    fn erdos_renyi_edge_count_exact() {
        let mut rng = Rng::new(53);
        let g = erdos_renyi(&mut rng, 100, 250);
        assert_eq!(g.adj.nnz(), 500);
        assert!(g.adj.is_symmetric(0.0));
    }

    #[test]
    fn barabasi_albert_is_connected_heavy_tailed() {
        let mut rng = Rng::new(54);
        let g = barabasi_albert(&mut rng, 500, 2);
        let (_, ncomp) = connected_components(&g.adj);
        assert_eq!(ncomp, 1, "BA graph should be connected");
        let degs = g.adj.row_sums();
        let max_deg = degs.iter().cloned().fold(0.0, f64::max);
        let avg = degs.iter().sum::<f64>() / degs.len() as f64;
        assert!(max_deg > 5.0 * avg, "max {max_deg} avg {avg}");
    }

    #[test]
    fn knn_graph_degrees_at_least_k() {
        let mut rng = Rng::new(55);
        let (pts, _) = gaussian_mixture(&mut rng, 60, 3, 3, 4.0);
        let g = knn_graph(&pts, 60, 3, 4);
        assert!(g.is_symmetric(0.0));
        for d in g.row_sums() {
            assert!(d >= 4.0, "degree {d} < k");
        }
    }

    #[test]
    fn gaussian_mixture_separation() {
        let mut rng = Rng::new(56);
        let (pts, labels) = gaussian_mixture(&mut rng, 200, 2, 2, 10.0);
        // Mean distance within cluster << between clusters (sep 10 sigma).
        let centroid = |c: usize| -> Vec<f64> {
            let idx: Vec<usize> = (0..200).filter(|&i| labels[i] == c).collect();
            let mut m = vec![0.0; 2];
            for &i in &idx {
                m[0] += pts[i * 2];
                m[1] += pts[i * 2 + 1];
            }
            m.iter().map(|v| v / idx.len() as f64).collect()
        };
        let c0 = centroid(0);
        let c1 = centroid(1);
        let dist = ((c0[0] - c1[0]).powi(2) + (c0[1] - c1[1]).powi(2)).sqrt();
        assert!(dist > 3.0, "centroid separation {dist}");
    }

    #[test]
    fn poisson_mean_roughly_right() {
        let mut rng = Rng::new(57);
        let n = 3000;
        let s: usize = (0..n).map(|_| poisson(&mut rng, 4.0)).sum();
        let mean = s as f64 / n as f64;
        assert!((mean - 4.0).abs() < 0.25, "poisson mean {mean}");
        let s2: usize = (0..n).map(|_| poisson(&mut rng, 200.0)).sum();
        let mean2 = s2 as f64 / n as f64;
        assert!((mean2 - 200.0).abs() < 2.0, "poisson mean {mean2}");
    }
}
