//! One-shot runtime kernel autotuner.
//!
//! `--tune` micro-benchmarks the fused SpMM on the *actual* matrix at
//! job start: lane-width cap {16 where profitable, 8, 4, 1} ×
//! row/slice-block nonzero budget {16 Ki, 32 Ki, 64 Ki} × storage
//! format {CSR, SELL-C-σ}, then runs the job with the fastest point.
//! Results are cached per `(rows, nnz, d)` shape for the life of the
//! process, so repeated jobs on the same matrix pay the sweep once;
//! tuning time is reported through the `obs` "autotune" stage and in
//! [`TunePoint::tune_ms`].
//!
//! Tuning is pure performance policy: every candidate produces
//! bitwise-identical output (asserted in `par_determinism`), so a wrong
//! pick can only cost time, never correctness.

use std::sync::Mutex;

use super::csr::{Csr, KernelCfg};
use super::sellcs::SellCs;
use crate::linalg::Mat;
use crate::par::{ExecPolicy, Workspace};
use crate::util::rng::Rng;
use crate::util::timer;

/// Storage format the sweep found fastest.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunedFormat {
    Csr,
    Sell,
}

/// Autotune result: the winning format and kernel configuration, plus
/// the measurements behind the choice.
#[derive(Clone, Copy, Debug)]
pub struct TunePoint {
    pub format: TunedFormat,
    pub cfg: KernelCfg,
    /// Best CSR candidate's throughput (GFLOP/s, 2·nnz·d per product).
    pub csr_gflops: f64,
    /// Best SELL candidate's throughput (0 when SELL was not swept).
    pub sell_gflops: f64,
    /// Wall-clock cost of the sweep (0 on a cache hit).
    pub tune_ms: f64,
    /// Whether this point came from the in-process shape cache.
    pub cached: bool,
    /// Whether NUMA first-touch placement of the winning format's
    /// arrays measured faster than the untouched layout. Only ever
    /// `true` on multi-node hosts — on one node placement is an
    /// intentional no-op and the axis is skipped.
    pub placed: bool,
}

/// Per-process tune cache keyed by `(rows, nnz, d)`. A const-init
/// assoc-list `Mutex<Vec<..>>` keeps the crate dependency-free; tune
/// sweeps are rare, so linear scans are irrelevant.
static CACHE: Mutex<Vec<((usize, usize, usize), TunePoint)>> = Mutex::new(Vec::new());

/// Row/slice-block nonzero budgets the sweep tries.
const ROW_BLOCKS: [usize; 3] = [16 * 1024, 32 * 1024, 64 * 1024];

/// Measure lane caps × block budgets × formats on `a` for RHS width `d`
/// and return the fastest point. Serial kernels are timed — the knobs
/// shape per-core work, and threading splits the same loops.
pub fn tune(a: &Csr, d: usize) -> TunePoint {
    let d = d.max(1);
    let key = (a.rows, a.nnz(), d);
    if let Some((_, hit)) = CACHE.lock().unwrap().iter().find(|(k, _)| *k == key) {
        let mut p = *hit;
        p.cached = true;
        p.tune_ms = 0.0;
        return p;
    }
    let point = sweep(a, d);
    CACHE.lock().unwrap().push((key, point));
    point
}

fn sweep(a: &Csr, d: usize) -> TunePoint {
    let default = TunePoint {
        format: TunedFormat::Csr,
        cfg: KernelCfg::default(),
        csr_gflops: 0.0,
        sell_gflops: 0.0,
        tune_ms: 0.0,
        cached: false,
        placed: false,
    };
    if a.rows == 0 || a.nnz() == 0 {
        return default;
    }
    let _span = crate::obs::span(&crate::obs::AUTOTUNE);
    let t = timer::Timer::start();

    let mut rng = Rng::new(0x5e11_c516);
    let x = Mat::randn(&mut rng, a.cols, d);
    let mut y = Mat::zeros(a.rows, d);
    let z = Mat::zeros(a.rows, d);
    let exec = ExecPolicy::serial();
    let mut ws = Workspace::new();
    let flops = 2.0 * a.nnz() as f64 * d as f64;
    // Keep the sweep cheap on huge matrices: one timed reps after the
    // harness warm-up, three on small ones where noise matters more.
    let reps = if flops > 4e8 { 1 } else { 3 };
    let mut tiles = vec![8usize, 4, 1];
    if d >= 16 {
        tiles.insert(0, 16);
    }

    let mut best_csr: (f64, KernelCfg) = (f64::INFINITY, KernelCfg::default());
    for &max_tile in &tiles {
        for &row_block_nnz in &ROW_BLOCKS {
            let cfg = KernelCfg { max_tile, row_block_nnz };
            let s = timer::bench(reps, || {
                a.spmm_axpby_into_ws_cfg(&x, 1.0, 0.0, &z, &mut y, &exec, &mut ws, cfg)
            });
            if s.mean_secs < best_csr.0 {
                best_csr = (s.mean_secs, cfg);
            }
        }
    }

    // SELL sweep reuses the winning block budget: the budget bounds the
    // same cache-residency trade-off in both layouts.
    let mut best_sell: (f64, KernelCfg) = (f64::INFINITY, best_csr.1);
    let sell_mat = SellCs::from_csr_default(a).ok();
    if let Some(sell) = &sell_mat {
        for &max_tile in &tiles {
            let cfg = KernelCfg { max_tile, row_block_nnz: best_csr.1.row_block_nnz };
            let s = timer::bench(reps, || {
                sell.spmm_axpby_into_ws_cfg(&x, 1.0, 0.0, &z, &mut y, &exec, &mut ws, cfg)
            });
            if s.mean_secs < best_sell.0 {
                best_sell = (s.mean_secs, cfg);
            }
        }
    }

    let (format, cfg) = if best_sell.0 < best_csr.0 {
        (TunedFormat::Sell, best_sell.1)
    } else {
        (TunedFormat::Csr, best_csr.1)
    };

    // Placement axis: on multi-node hosts, measure whether NUMA
    // first-touch placement of the winning format's arrays (threaded
    // partition over physical cores, so each node's workers touch the
    // pages they will later compute) beats the untouched layout under
    // the same threaded policy. On one node the axis is skipped —
    // placement cannot move any page to a different node.
    let topo = crate::par::topo::detect();
    let mut placed = false;
    if topo.num_nodes() > 1 {
        let pexec = ExecPolicy::with_threads(topo.physical_cores());
        placed = match format {
            TunedFormat::Csr => {
                let mut b = a.clone();
                let t0 = timer::bench(reps, || {
                    a.spmm_axpby_into_ws_cfg(&x, 1.0, 0.0, &z, &mut y, &pexec, &mut ws, cfg)
                });
                b.place(&pexec);
                let t1 = timer::bench(reps, || {
                    b.spmm_axpby_into_ws_cfg(&x, 1.0, 0.0, &z, &mut y, &pexec, &mut ws, cfg)
                });
                t1.mean_secs < t0.mean_secs
            }
            TunedFormat::Sell => match &sell_mat {
                Some(sell) => {
                    let mut b = sell.clone();
                    let t0 = timer::bench(reps, || {
                        sell.spmm_axpby_into_ws_cfg(&x, 1.0, 0.0, &z, &mut y, &pexec, &mut ws, cfg)
                    });
                    b.place(&pexec);
                    let t1 = timer::bench(reps, || {
                        b.spmm_axpby_into_ws_cfg(&x, 1.0, 0.0, &z, &mut y, &pexec, &mut ws, cfg)
                    });
                    t1.mean_secs < t0.mean_secs
                }
                None => false,
            },
        };
    }

    TunePoint {
        format,
        cfg,
        csr_gflops: flops / best_csr.0 / 1e9,
        sell_gflops: if best_sell.0.is_finite() { flops / best_sell.0 / 1e9 } else { 0.0 },
        tune_ms: t.elapsed_secs() * 1e3,
        cached: false,
        placed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn tune_returns_valid_point_and_caches_by_shape() {
        let mut rng = Rng::new(907);
        let g = gen::barabasi_albert(&mut rng, 300, 3);
        let na = crate::sparse::graph::normalized_adjacency(&g.adj);
        let p = tune(&na, 8);
        assert!(p.cfg.max_tile >= 1 && p.cfg.row_block_nnz >= ROW_BLOCKS[0]);
        assert!(p.csr_gflops > 0.0 && p.sell_gflops > 0.0);
        assert!(!p.cached && p.tune_ms >= 0.0);
        let p2 = tune(&na, 8);
        assert!(p2.cached, "second call with the same shape must hit the cache");
        assert_eq!(p2.format, p.format);
        assert_eq!(p2.cfg, p.cfg);
        // Different d is a different shape: fresh sweep.
        let p3 = tune(&na, 16);
        assert!(!p3.cached);
    }

    #[test]
    fn tune_handles_degenerate_matrices() {
        let empty = Csr::from_coo(&crate::sparse::Coo::new(0, 0));
        let p = tune(&empty, 4);
        assert_eq!(p.format, TunedFormat::Csr);
        assert_eq!(p.cfg, KernelCfg::default());
    }
}
