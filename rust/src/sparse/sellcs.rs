//! SELL-C-σ sparse storage: sliced ELLPACK with σ-window row sorting.
//!
//! CSR's row-blocked lanes pay per-row overhead (accumulator init, lane
//! write-back, loop control) once per row per lane; on skewed degree
//! distributions — power-law graphs, the paper's DBLP/Amazon-style
//! networks — most rows are short and that overhead dominates. SELL-C-σ
//! amortizes it: rows are stably sorted by nonzero count inside
//! σ-row windows, packed into slices of `C` rows padded to the slice's
//! longest row, and stored column-major within the slice so the kernel
//! sweeps `C` rows in lockstep with contiguous `(u32 index, f64 value)`
//! loads.
//!
//! ## Bitwise contract
//!
//! Output is **bitwise-identical to the CSR kernels** at any thread
//! count, tile cap, and slice height:
//!
//! - a row's nonzeros keep their original (column-sorted) order, so each
//!   accumulator sees the identical float-op sequence;
//! - padding slots store the explicit value `+0.0` with column 0, and
//!   are appended *after* the row's real nonzeros, so each pad step adds
//!   `0.0 * x[c] = ±0.0` to an accumulator that is never `-0.0` (it
//!   starts at `+0.0`, and IEEE-754 round-to-nearest addition only
//!   yields `-0.0` from `(-0.0) + (-0.0)`) — the accumulator bits are
//!   unchanged. This argument needs finite `x`; [`super::Csr::validate`]
//!   keeps non-finite values out of the matrix, and the recurrence's
//!   blow-up guard discards shard outputs whose iterates go non-finite
//!   before they reach a result;
//! - the write-back is the same pinned three-case expression as CSR's
//!   `fused_lane` (`beta != 0`, then `alpha != 1`, then plain store),
//!   and — like the whole kernel stack — never uses FMA contraction.
//!
//! The σ-window sort only permutes *which slice slot computes which
//! row*; results scatter back through the slot→row permutation, so the
//! output layout (and every bit in it) matches CSR.
//!
//! Cancellation is polled at slice-block granularity (the same stored-
//! entry budget CSR uses for row blocks); a cancelled product returns
//! with the output partially written and the caller discards it.

use std::ops::Range;

use super::csr::{ensure_u32_indexable, Csr, CsrError, KernelCfg};
use crate::linalg::Mat;
use crate::par::{self, CancelToken, ExecPolicy, Workspace};

/// Sentinel in `perm` marking a padding slot with no source row (only
/// present in the final slice when `rows % chunk != 0`).
pub const PAD_SLOT: u32 = u32::MAX;

/// Default slice height C: matches the widest column lane, so a full
/// slice's accumulators tile the registers evenly.
pub const DEFAULT_CHUNK: usize = 8;

/// Default sorting window σ: large enough to group like-degree rows,
/// small enough that the slot→row permutation stays cache-local.
pub const DEFAULT_SIGMA: usize = 256;

/// SELL-C-σ matrix (`f64` values, u32 column indices).
///
/// Entry `r` of slice `s` at depth `k` lives at
/// `slice_ptr[s] + k * chunk + r` — column-major within the slice, so a
/// depth step loads `chunk` contiguous index/value pairs.
#[derive(Clone, Debug)]
pub struct SellCs {
    pub rows: usize,
    pub cols: usize,
    /// Slice height C (rows per slice).
    pub chunk: usize,
    /// Sorting window σ (rows), rounded down to a multiple of `chunk`.
    pub sigma: usize,
    /// Slot → original row, length `n_slices * chunk`; [`PAD_SLOT`] for
    /// slots past the last real row.
    pub perm: Vec<u32>,
    /// Slice offsets into `indices`/`values`, length `n_slices + 1`.
    /// Counts stored entries *including padding*, so it doubles as the
    /// weight prefix for nnz-balanced slice partitioning.
    pub slice_ptr: Vec<usize>,
    /// True nonzero count per slot (0 for pad slots), length
    /// `n_slices * chunk`.
    pub rlen: Vec<u32>,
    /// Column indices, padded entries store 0.
    pub indices: Vec<u32>,
    /// Values, padded entries store `+0.0`.
    pub values: Vec<f64>,
    /// True nonzero count (excludes padding).
    nnz: usize,
}

/// `*mut f64` allowed across the pool's thread boundary. Safety rests on
/// the slice partition: each task writes only the output rows of its own
/// slices, and `perm` maps every slot of every slice to a distinct row
/// (it is a permutation), so concurrent tasks never touch the same
/// element. Mirrors `par`'s private `SendPtr`, which stays private to
/// keep arbitrary scatter out of the safe API.
struct YPtr(*mut f64);
unsafe impl Send for YPtr {}
unsafe impl Sync for YPtr {}

impl SellCs {
    /// Pack a CSR matrix into SELL-C-σ with the default slice height and
    /// sorting window.
    pub fn from_csr_default(a: &Csr) -> Result<SellCs, CsrError> {
        Self::from_csr(a, DEFAULT_CHUNK, DEFAULT_SIGMA)
    }

    /// Pack a CSR matrix into SELL-C-σ: stable-sort rows by descending
    /// nonzero count within σ-row windows, cut the sorted order into
    /// slices of `chunk` rows, and pad each slice to its longest row.
    ///
    /// `sigma` is rounded down to a multiple of `chunk` (minimum
    /// `chunk`) so slices never straddle a window boundary. Rejects
    /// dimensions beyond the u32 index range with the same typed error
    /// as CSR ingestion (`perm` and `indices` are u32).
    pub fn from_csr(a: &Csr, chunk: usize, sigma: usize) -> Result<SellCs, CsrError> {
        ensure_u32_indexable(a.cols)?;
        ensure_u32_indexable(a.rows)?;
        let chunk = chunk.max(1);
        let sigma = (sigma.max(chunk) / chunk) * chunk;
        let n_slices = a.rows.div_ceil(chunk);
        let slots = n_slices * chunk;

        // Stable nnz-descending sort inside each σ window: equal-degree
        // rows keep their relative order, so packing is deterministic.
        let mut order: Vec<u32> = (0..a.rows as u32).collect();
        for w in order.chunks_mut(sigma) {
            w.sort_by_key(|&i| {
                std::cmp::Reverse(a.indptr[i as usize + 1] - a.indptr[i as usize])
            });
        }

        let mut perm = vec![PAD_SLOT; slots];
        let mut rlen = vec![0u32; slots];
        for (slot, &row) in order.iter().enumerate() {
            perm[slot] = row;
            rlen[slot] = (a.indptr[row as usize + 1] - a.indptr[row as usize]) as u32;
        }

        let mut slice_ptr = vec![0usize; n_slices + 1];
        for s in 0..n_slices {
            let len = (0..chunk).map(|r| rlen[s * chunk + r] as usize).max().unwrap_or(0);
            slice_ptr[s + 1] = slice_ptr[s] + chunk * len;
        }

        let stored = slice_ptr[n_slices];
        let mut indices = vec![0u32; stored];
        let mut values = vec![0.0f64; stored];
        for s in 0..n_slices {
            let off = slice_ptr[s];
            for r in 0..chunk {
                let slot = s * chunk + r;
                if perm[slot] == PAD_SLOT {
                    continue;
                }
                let (idx, val) = a.row(perm[slot] as usize);
                for (k, (&j, &v)) in idx.iter().zip(val).enumerate() {
                    let e = off + k * chunk + r;
                    indices[e] = j;
                    values[e] = v;
                }
            }
        }

        Ok(SellCs {
            rows: a.rows,
            cols: a.cols,
            chunk,
            sigma,
            perm,
            slice_ptr,
            rlen,
            indices,
            values,
            nnz: a.nnz(),
        })
    }

    /// True nonzero count (padding excluded).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Stored entry count including padding.
    pub fn stored(&self) -> usize {
        self.indices.len()
    }

    /// Fraction of stored entries that are padding (0 for an empty
    /// matrix). The σ sort exists to keep this small on skewed degrees.
    pub fn padding_ratio(&self) -> f64 {
        if self.stored() == 0 {
            return 0.0;
        }
        (self.stored() - self.nnz) as f64 / self.stored() as f64
    }

    pub fn n_slices(&self) -> usize {
        self.slice_ptr.len() - 1
    }

    /// Depth (entries per slot) of slice `s`.
    pub fn slice_len(&self, s: usize) -> usize {
        (self.slice_ptr[s + 1] - self.slice_ptr[s]) / self.chunk
    }

    /// NUMA first-touch placement: re-materialize the packed index and
    /// value arrays so each parallel worker first-touches exactly the
    /// pages backing the slice range it will later compute, using the
    /// same entry-balanced partition the SELL kernels derive from
    /// `exec`. The SELL counterpart of [`Csr::place`]: contents are
    /// copied verbatim and `slice_ptr` stays in place (it keys the
    /// sticky partition), so placement is bitwise-invisible.
    pub fn place(&mut self, exec: &ExecPolicy) {
        if self.n_slices() == 0 || self.stored() == 0 || exec.is_serial() {
            return;
        }
        let _span = crate::obs::span(&crate::obs::NUMA_PLACE);
        let ranges = par::weighted_ranges(&self.slice_ptr, exec.chunks(self.n_slices()));
        let stored = self.stored();
        // Fresh zeroed Vecs come from lazily-mapped pages (untouched
        // until written), so the parallel copy below is the first touch.
        let mut values = vec![0.0f64; stored];
        let mut indices = vec![0u32; stored];
        struct SendMut<T>(*mut T);
        unsafe impl<T> Send for SendMut<T> {}
        unsafe impl<T> Sync for SendMut<T> {}
        let vp = SendMut(values.as_mut_ptr());
        let ip = SendMut(indices.as_mut_ptr());
        let ranges = &ranges;
        exec.run_indexed(ranges.len(), |k| {
            let r = &ranges[k];
            let (s, e) = (self.slice_ptr[r.start], self.slice_ptr[r.end]);
            // SAFETY: the slice partition is ascending, contiguous, and
            // covering, so `[s, e)` segments are disjoint across `k` and
            // in-bounds; each element is written by exactly one worker
            // and the Vecs outlive the region.
            unsafe {
                std::ptr::copy_nonoverlapping(self.values.as_ptr().add(s), vp.0.add(s), e - s);
                std::ptr::copy_nonoverlapping(self.indices.as_ptr().add(s), ip.0.add(s), e - s);
            }
        });
        self.values = values;
        self.indices = indices;
    }

    /// Memory footprint in bytes (metrics/reporting).
    pub fn mem_bytes(&self) -> usize {
        self.slice_ptr.len() * 8
            + self.perm.len() * 4
            + self.rlen.len() * 4
            + self.indices.len() * 4
            + self.values.len() * 8
    }

    /// Unpack back to CSR. Exact round-trip: rows keep their original
    /// (column-sorted) entry order, so `to_csr` of `from_csr(a, ..)`
    /// reproduces `a`'s arrays bit-for-bit.
    pub fn to_csr(&self) -> Csr {
        let mut indptr = vec![0usize; self.rows + 1];
        for (slot, &row) in self.perm.iter().enumerate() {
            if row != PAD_SLOT {
                indptr[row as usize + 1] = self.rlen[slot] as usize;
            }
        }
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        let mut indices = vec![0u32; self.nnz];
        let mut values = vec![0.0f64; self.nnz];
        for s in 0..self.n_slices() {
            let off = self.slice_ptr[s];
            for r in 0..self.chunk {
                let slot = s * self.chunk + r;
                let row = self.perm[slot];
                if row == PAD_SLOT {
                    continue;
                }
                let base = indptr[row as usize];
                for k in 0..self.rlen[slot] as usize {
                    let e = off + k * self.chunk + r;
                    indices[base + k] = self.indices[e];
                    values[base + k] = self.values[e];
                }
            }
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// y = A x (single vector), serial wrapper.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_with(x, &ExecPolicy::serial())
    }

    /// y = A x with slice-partitioned threading. Bitwise-identical to
    /// [`Csr::matvec`] at any thread count.
    pub fn matvec_with(&self, x: &[f64], exec: &ExecPolicy) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        let cfg = KernelCfg::default();
        if exec.is_serial() || self.n_slices() <= 1 {
            // SAFETY: exclusive access to `y`, which has `rows` elements
            // (d = 1); slices cover distinct rows via `perm`.
            let all = 0..self.n_slices();
            unsafe { self.slices_fused(x, 1, all, y.as_mut_ptr(), 1.0, 0.0, &[], cfg, None) };
            return y;
        }
        let mut ranges = Vec::new();
        par::weighted_ranges_into(&self.slice_ptr, exec.chunks(self.n_slices()), &mut ranges);
        let yp = YPtr(y.as_mut_ptr());
        exec.run_indexed(ranges.len(), |k| {
            // SAFETY: tasks own disjoint slice ranges; `perm` is a
            // permutation, so their output rows are disjoint too.
            unsafe {
                self.slices_fused(x, 1, ranges[k].clone(), yp.0, 1.0, 0.0, &[], cfg, None)
            };
        });
        y
    }

    /// Y = A X into a preallocated output, partition scratch drawn from
    /// `ws` — the allocation-free steady-state form, mirroring
    /// [`Csr::spmm_into_ws`] (and bitwise-identical to it).
    pub fn spmm_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.spmm_into_ws_cfg(x, y, exec, ws, KernelCfg::default());
    }

    /// [`Self::spmm_into_ws`] with an explicit kernel configuration
    /// (autotuner output). `cfg` moves lane and block boundaries only —
    /// the output bits cannot change.
    pub fn spmm_into_ws_cfg(
        &self,
        x: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
        cfg: KernelCfg,
    ) {
        assert_eq!(x.rows, self.cols, "spmm shape mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        self.fused_dispatch(x, 1.0, 0.0, &[], y, exec, ws, cfg);
    }

    /// Fused `y = alpha·(A·x) + beta·z` with slice-partitioned threading
    /// and workspace-backed scratch, mirroring
    /// [`Csr::spmm_axpby_into_ws`] (and bitwise-identical to it at any
    /// thread count, tile cap, and slice height).
    pub fn spmm_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        self.spmm_axpby_into_ws_cfg(x, alpha, beta, z, y, exec, ws, KernelCfg::default());
    }

    /// [`Self::spmm_axpby_into_ws`] with an explicit kernel
    /// configuration (autotuner output).
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_axpby_into_ws_cfg(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
        cfg: KernelCfg,
    ) {
        assert_eq!(x.rows, self.cols, "spmm shape mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        assert_eq!((z.rows, z.cols), (y.rows, y.cols), "z must match the output shape");
        self.fused_dispatch(x, alpha, beta, &z.data, y, exec, ws, cfg);
    }

    /// Test-only entry: serial fused product with the lane width capped
    /// at `max_tile`, for asserting the cap is bitwise-invisible (the
    /// SELL counterpart of [`Csr::spmm_axpby_max_tile`]).
    #[doc(hidden)]
    pub fn spmm_axpby_max_tile(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        max_tile: usize,
    ) {
        assert_eq!(x.rows, self.cols, "spmm shape mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        assert_eq!((z.rows, z.cols), (y.rows, y.cols));
        let cfg = KernelCfg { max_tile: max_tile.max(1), ..KernelCfg::default() };
        // SAFETY: exclusive access to `y` with the full `rows * d` shape.
        unsafe {
            self.slices_fused(
                &x.data,
                x.cols,
                0..self.n_slices(),
                y.data.as_mut_ptr(),
                alpha,
                beta,
                &z.data,
                cfg,
                None,
            )
        };
    }

    /// Shared serial/parallel dispatch for the fused product. `z` is
    /// empty for the plain product (`beta == 0` never reads it).
    #[allow(clippy::too_many_arguments)]
    fn fused_dispatch(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &[f64],
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
        cfg: KernelCfg,
    ) {
        let _span = crate::obs::span(&crate::obs::SPMM);
        let d = x.cols;
        let cancel = ws.cancel.clone();
        if exec.is_serial() || self.n_slices() <= 1 {
            // SAFETY: exclusive `&mut y` covers all written rows.
            unsafe {
                self.slices_fused(
                    &x.data,
                    d,
                    0..self.n_slices(),
                    y.data.as_mut_ptr(),
                    alpha,
                    beta,
                    z,
                    cfg,
                    cancel.as_ref(),
                )
            };
            return;
        }
        let mut ranges = std::mem::take(&mut ws.slice_ranges);
        par::weighted_ranges_sticky(
            &self.slice_ptr,
            exec.chunks(self.n_slices()),
            &mut ranges,
            &mut ws.slice_ranges_key,
        );
        let yp = YPtr(y.data.as_mut_ptr());
        let xs = &x.data;
        exec.run_indexed(ranges.len(), |k| {
            // SAFETY: tasks own disjoint slice ranges, and `perm` maps
            // every slot to a distinct output row, so no element of `y`
            // is written by two tasks. `y` outlives the region (we hold
            // `&mut y` across `run_indexed`).
            let r = ranges[k].clone();
            unsafe { self.slices_fused(xs, d, r, yp.0, alpha, beta, z, cfg, cancel.as_ref()) };
        });
        ws.slice_ranges = ranges;
    }

    /// Process slices `slices`, polling cancellation once per
    /// `cfg.row_block_nnz` stored entries (the CSR row-block budget). A
    /// cancelled call returns immediately; the caller that observed
    /// cancellation discards the partially-written output.
    ///
    /// # Safety
    ///
    /// `y` must be valid for writes of `rows * d` elements, and the
    /// caller must guarantee no concurrent access to the output rows of
    /// `slices` (disjoint slice ranges from one partition are safe:
    /// `perm` is a permutation).
    #[allow(clippy::too_many_arguments)]
    unsafe fn slices_fused(
        &self,
        x: &[f64],
        d: usize,
        slices: Range<usize>,
        y: *mut f64,
        alpha: f64,
        beta: f64,
        z: &[f64],
        cfg: KernelCfg,
        cancel: Option<&CancelToken>,
    ) {
        debug_assert!(beta == 0.0 || z.len() >= self.rows * d);
        let mut s = slices.start;
        while s < slices.end {
            if let Some(c) = cancel {
                if c.is_cancelled() {
                    return;
                }
            }
            let budget = self.slice_ptr[s] + cfg.row_block_nnz;
            let mut e = s + 1;
            while e < slices.end && self.slice_ptr[e + 1] <= budget {
                e += 1;
            }
            for si in s..e {
                unsafe { self.slice_fused(x, d, si, y, alpha, beta, z, cfg.max_tile) };
            }
            s = e;
        }
    }

    /// Sweep one slice: the same column-lane cascade as CSR's
    /// `fused_block` (16 when the autotuner raised the cap, then 8, 4,
    /// scalar), with each lane processing the slice's rows in groups of
    /// four.
    #[allow(clippy::too_many_arguments)]
    unsafe fn slice_fused(
        &self,
        x: &[f64],
        d: usize,
        s: usize,
        y: *mut f64,
        alpha: f64,
        beta: f64,
        z: &[f64],
        max_tile: usize,
    ) {
        let mut c0 = 0;
        while c0 + 16 <= d && max_tile >= 16 {
            unsafe { self.slice_lane::<16>(x, d, c0, s, y, alpha, beta, z) };
            c0 += 16;
        }
        while c0 + 8 <= d && max_tile >= 8 {
            unsafe { self.slice_lane8(x, d, c0, s, y, alpha, beta, z) };
            c0 += 8;
        }
        while c0 + 4 <= d && max_tile >= 4 {
            unsafe { self.slice_lane::<4>(x, d, c0, s, y, alpha, beta, z) };
            c0 += 4;
        }
        while c0 < d {
            unsafe { self.slice_lane::<1>(x, d, c0, s, y, alpha, beta, z) };
            c0 += 1;
        }
    }

    /// One lane over one slice: slots in groups of four (scalar
    /// remainder for slice heights not divisible by four).
    #[allow(clippy::too_many_arguments)]
    unsafe fn slice_lane<const W: usize>(
        &self,
        x: &[f64],
        d: usize,
        c0: usize,
        s: usize,
        y: *mut f64,
        alpha: f64,
        beta: f64,
        z: &[f64],
    ) {
        let chunk = self.chunk;
        let off = self.slice_ptr[s];
        let len = self.slice_len(s);
        let slot0 = s * chunk;
        let mut r = 0;
        while r + 4 <= chunk {
            unsafe { self.group_lane::<W, 4>(x, d, c0, off, len, slot0 + r, r, y, alpha, beta, z) };
            r += 4;
        }
        while r < chunk {
            unsafe { self.group_lane::<W, 1>(x, d, c0, off, len, slot0 + r, r, y, alpha, beta, z) };
            r += 1;
        }
    }

    /// The width-8 lane, with the explicit-SIMD fast path when the
    /// `simd` feature is on and the host supports it (scalar fallback
    /// otherwise — same float ops in the same order either way).
    #[allow(clippy::too_many_arguments)]
    unsafe fn slice_lane8(
        &self,
        x: &[f64],
        d: usize,
        c0: usize,
        s: usize,
        y: *mut f64,
        alpha: f64,
        beta: f64,
        z: &[f64],
    ) {
        let chunk = self.chunk;
        let off = self.slice_ptr[s];
        let len = self.slice_len(s);
        let slot0 = s * chunk;
        let mut r = 0;
        #[cfg(feature = "simd")]
        if super::simd::lane8_fast() {
            while r + 4 <= chunk {
                let mut acc = [[0.0f64; 8]; 4];
                // SAFETY: `lane8_fast` checked the required CPU feature;
                // entry/row bounds hold by the packing invariants.
                unsafe {
                    super::simd::sell_acc8x4(
                        &self.values,
                        &self.indices,
                        off + r,
                        chunk,
                        len,
                        x,
                        d,
                        c0,
                        &mut acc,
                    );
                    self.write_group::<8, 4>(&acc, slot0 + r, d, c0, y, alpha, beta, z);
                }
                r += 4;
            }
        }
        while r + 4 <= chunk {
            unsafe { self.group_lane::<8, 4>(x, d, c0, off, len, slot0 + r, r, y, alpha, beta, z) };
            r += 4;
        }
        while r < chunk {
            unsafe { self.group_lane::<8, 1>(x, d, c0, off, len, slot0 + r, r, y, alpha, beta, z) };
            r += 1;
        }
    }

    /// Accumulate and write one group of `G` slots over lane columns
    /// `[c0, c0 + W)`. The k-loop walks each slot's entries in original
    /// column order; pad entries (`+0.0`, column 0) come after the real
    /// ones and cannot change the accumulator bits (module docs).
    #[allow(clippy::too_many_arguments)]
    unsafe fn group_lane<const W: usize, const G: usize>(
        &self,
        x: &[f64],
        d: usize,
        c0: usize,
        off: usize,
        len: usize,
        slot0: usize,
        r0: usize,
        y: *mut f64,
        alpha: f64,
        beta: f64,
        z: &[f64],
    ) {
        let chunk = self.chunk;
        let mut acc = [[0.0f64; W]; G];
        for k in 0..len {
            let e = off + k * chunk + r0;
            let ev = &self.values[e..e + G];
            let ei = &self.indices[e..e + G];
            for g in 0..G {
                let aij = ev[g];
                let base = ei[g] as usize * d + c0;
                let xr: &[f64; W] = x[base..base + W].try_into().unwrap();
                for c in 0..W {
                    acc[g][c] += aij * xr[c];
                }
            }
        }
        unsafe { self.write_group::<W, G>(&acc, slot0, d, c0, y, alpha, beta, z) };
    }

    /// Scatter one group's accumulators to their original rows with the
    /// pinned CSR write-back expression. Pad slots are skipped.
    ///
    /// # Safety
    ///
    /// `y` valid for `rows * d` writes; exclusive access to the group's
    /// output rows.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    unsafe fn write_group<const W: usize, const G: usize>(
        &self,
        acc: &[[f64; W]; G],
        slot0: usize,
        d: usize,
        c0: usize,
        y: *mut f64,
        alpha: f64,
        beta: f64,
        z: &[f64],
    ) {
        for g in 0..G {
            let row = self.perm[slot0 + g];
            if row == PAD_SLOT {
                continue;
            }
            let ybase = row as usize * d + c0;
            if beta != 0.0 {
                let zr: &[f64; W] = z[ybase..ybase + W].try_into().unwrap();
                for c in 0..W {
                    unsafe { *y.add(ybase + c) = alpha * acc[g][c] + beta * zr[c] };
                }
            } else if alpha != 1.0 {
                for c in 0..W {
                    unsafe { *y.add(ybase + c) = alpha * acc[g][c] };
                }
            } else {
                for c in 0..W {
                    unsafe { *y.add(ybase + c) = acc[g][c] };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Rng;

    fn random_csr(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> Csr {
        let mut c = Coo::new(rows, cols);
        for _ in 0..nnz {
            c.push(rng.below(rows), rng.below(cols), rng.normal());
        }
        Csr::from_coo(&c)
    }

    #[test]
    fn round_trip_is_exact() {
        let mut rng = Rng::new(901);
        let shapes = [(1usize, 1usize, 1usize), (7, 5, 10), (33, 40, 150), (64, 64, 0)];
        for &(rows, cols, nnz) in &shapes {
            let a = random_csr(&mut rng, rows, cols, nnz);
            for &(chunk, sigma) in &[(4usize, 16usize), (8, 256), (32, 32), (3, 7)] {
                let s = SellCs::from_csr(&a, chunk, sigma).unwrap();
                let back = s.to_csr();
                assert_eq!(back.indptr, a.indptr, "C={chunk} σ={sigma}");
                assert_eq!(back.indices, a.indices, "C={chunk} σ={sigma}");
                assert_eq!(back.values, a.values, "C={chunk} σ={sigma}");
                assert_eq!(s.nnz(), a.nnz());
            }
        }
    }

    #[test]
    fn sigma_windows_sort_and_perm_is_a_permutation() {
        let mut rng = Rng::new(902);
        let a = random_csr(&mut rng, 100, 60, 500);
        let s = SellCs::from_csr(&a, 4, 16).unwrap();
        // perm covers every row exactly once (plus pad sentinels).
        let mut seen = vec![false; a.rows];
        for &p in &s.perm {
            if p != PAD_SLOT {
                assert!(!seen[p as usize], "row {p} packed twice");
                seen[p as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "some row never packed");
        // Inside each σ window, slot lengths are non-increasing.
        for w0 in (0..s.perm.len()).step_by(s.sigma) {
            let w1 = (w0 + s.sigma).min(s.perm.len());
            for t in w0 + 1..w1 {
                assert!(s.rlen[t] <= s.rlen[t - 1], "window not sorted at slot {t}");
            }
        }
    }

    #[test]
    fn padding_entries_are_exact_zero_and_counted() {
        let mut rng = Rng::new(903);
        // Skewed: a few heavy rows force padding in their slices.
        let mut c = Coo::new(40, 40);
        for j in 0..35 {
            c.push(0, j, rng.normal());
            c.push(17, j, rng.normal());
        }
        for i in 1..40 {
            c.push(i, rng.below(40), rng.normal());
        }
        let a = Csr::from_coo(&c);
        let s = SellCs::from_csr(&a, 8, 8).unwrap();
        assert_eq!(s.stored() - s.nnz(), {
            // Recompute padding directly from slot lengths.
            let mut pad = 0usize;
            for sl in 0..s.n_slices() {
                for r in 0..s.chunk {
                    pad += s.slice_len(sl) - s.rlen[sl * s.chunk + r] as usize;
                }
            }
            pad
        });
        // Every padded entry stores exactly +0.0 at column 0.
        for sl in 0..s.n_slices() {
            let off = s.slice_ptr[sl];
            for r in 0..s.chunk {
                let slot = sl * s.chunk + r;
                for k in s.rlen[slot] as usize..s.slice_len(sl) {
                    let e = off + k * s.chunk + r;
                    assert_eq!(s.values[e].to_bits(), 0.0f64.to_bits());
                    assert_eq!(s.indices[e], 0);
                }
            }
        }
        assert!(s.padding_ratio() > 0.0);
    }

    #[test]
    fn matvec_matches_csr_bitwise() {
        let mut rng = Rng::new(904);
        for trial in 0..8 {
            let rows = 1 + rng.below(70);
            let cols = 1 + rng.below(70);
            let a = random_csr(&mut rng, rows, cols, rows * 3);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let want = a.matvec(&x);
            for &chunk in &[4usize, 8, 32] {
                let s = SellCs::from_csr(&a, chunk, 64).unwrap();
                assert_eq!(s.matvec(&x), want, "trial {trial} C={chunk}");
                for threads in [2usize, 4] {
                    let exec = ExecPolicy::with_threads(threads);
                    let got = s.matvec_with(&x, &exec);
                    assert_eq!(got, want, "trial {trial} C={chunk} t={threads}");
                }
            }
        }
    }

    #[test]
    fn empty_matrices_and_empty_rows() {
        let a = Csr::from_coo(&Coo::new(0, 0));
        let s = SellCs::from_csr(&a, 8, 256).unwrap();
        assert_eq!(s.n_slices(), 0);
        assert_eq!(s.matvec(&[]), Vec::<f64>::new());

        let a = Csr::from_coo(&Coo::new(5, 3)); // all rows empty
        let s = SellCs::from_csr(&a, 4, 4).unwrap();
        assert_eq!(s.stored(), 0);
        assert_eq!(s.matvec(&[1.0, 2.0, 3.0]), vec![0.0; 5]);
        let back = s.to_csr();
        assert_eq!(back.indptr, a.indptr);
    }

    #[test]
    fn rejects_dimensions_beyond_u32() {
        #[cfg(target_pointer_width = "64")]
        {
            let a = Csr {
                rows: 0,
                cols: u32::MAX as usize + 1,
                indptr: vec![0],
                indices: vec![],
                values: vec![],
            };
            assert!(matches!(
                SellCs::from_csr(&a, 8, 256),
                Err(CsrError::ColumnIndexOverflow { .. })
            ));
        }
    }
}
