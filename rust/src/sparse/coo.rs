//! Coordinate-format sparse matrix (builder format).

/// COO triplets. Duplicate entries are *summed* on conversion to CSR.
#[derive(Clone, Debug)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub entries: Vec<(usize, usize, f64)>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Self {
        Coo { rows, cols, entries: Vec::new() }
    }

    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols, "({i},{j}) out of {}x{}", self.rows, self.cols);
        self.entries.push((i, j, v));
    }

    /// Add both (i, j, v) and (j, i, v) — undirected-graph convenience.
    pub fn push_sym(&mut self, i: usize, j: usize, v: f64) {
        self.push(i, j, v);
        if i != j {
            self.push(j, i, v);
        }
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Build from an undirected edge list (unit weights, both directions).
    pub fn from_undirected_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut coo = Coo::new(n, n);
        for &(u, v) in edges {
            coo.push_sym(u, v, 1.0);
        }
        coo
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_sym_adds_both_directions() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 1, 2.0);
        c.push_sym(2, 2, 5.0); // diagonal: added once
        assert_eq!(c.entries, vec![(0, 1, 2.0), (1, 0, 2.0), (2, 2, 5.0)]);
    }

    #[test]
    fn from_undirected_edges_counts() {
        let c = Coo::from_undirected_edges(4, &[(0, 1), (1, 2)]);
        assert_eq!(c.nnz(), 4);
    }
}
