//! Graph-derived operators.
//!
//! The paper's experiments all run on the *normalized adjacency*
//! `Ã = D^{-1/2} A D^{-1/2}` (eigenvalues in [-1, 1]); §3.5 embeds general
//! `m x n` matrices via the symmetric dilation `S = [[0, A^T], [A, 0]]`.

use super::coo::Coo;
use super::csr::Csr;

/// Degrees (row sums) of an adjacency matrix; isolated vertices get 0.
pub fn degrees(adj: &Csr) -> Vec<f64> {
    adj.row_sums()
}

/// Normalized adjacency `D^{-1/2} A D^{-1/2}`. Isolated vertices (degree 0)
/// keep zero rows/cols. Eigenvalues land in [-1, 1].
pub fn normalized_adjacency(adj: &Csr) -> Csr {
    assert_eq!(adj.rows, adj.cols, "adjacency must be square");
    let d = degrees(adj);
    let dinv_sqrt: Vec<f64> = d
        .iter()
        .map(|&x| if x > 0.0 { 1.0 / x.sqrt() } else { 0.0 })
        .collect();
    let mut out = adj.clone();
    out.diag_scale(&dinv_sqrt, &dinv_sqrt);
    out
}

/// Random-walk transition matrix `D^{-1} A` (rows sum to 1 on non-isolated
/// vertices) — the operator behind power-iteration clustering [18].
pub fn random_walk_matrix(adj: &Csr) -> Csr {
    assert_eq!(adj.rows, adj.cols);
    let d = degrees(adj);
    let dinv: Vec<f64> = d.iter().map(|&x| if x > 0.0 { 1.0 / x } else { 0.0 }).collect();
    let ones = vec![1.0; adj.cols];
    let mut out = adj.clone();
    out.diag_scale(&dinv, &ones);
    out
}

/// Combinatorial Laplacian `L = D - A`.
pub fn laplacian(adj: &Csr) -> Csr {
    assert_eq!(adj.rows, adj.cols);
    let d = degrees(adj);
    let mut coo = Coo::new(adj.rows, adj.cols);
    for i in 0..adj.rows {
        let (idx, val) = adj.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            coo.push(i, j as usize, -v);
        }
        coo.push(i, i, d[i]);
    }
    Csr::from_coo(&coo)
}

/// Normalized Laplacian `I - D^{-1/2} A D^{-1/2}` (eigenvalues in [0, 2]).
pub fn normalized_laplacian(adj: &Csr) -> Csr {
    let na = normalized_adjacency(adj);
    let mut coo = Coo::new(adj.rows, adj.cols);
    for i in 0..na.rows {
        let (idx, val) = na.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            coo.push(i, j as usize, -v);
        }
        coo.push(i, i, 1.0);
    }
    Csr::from_coo(&coo)
}

/// Symmetric dilation `S = [[0, A^T], [A, 0]]` of an `m x n` matrix
/// (paper §3.5). Rows 0..n of S correspond to *columns* of A, rows n..n+m
/// to *rows* of A; eigenvalues are ±σ_l plus |m−n| zeros.
pub fn dilation(a: &Csr) -> Csr {
    let (m, n) = (a.rows, a.cols);
    let at = a.transpose();
    let size = m + n;
    let mut indptr = Vec::with_capacity(size + 1);
    let mut indices = Vec::with_capacity(2 * a.nnz());
    let mut values = Vec::with_capacity(2 * a.nnz());
    indptr.push(0);
    // First n rows: [0, A^T] -> A^T's row i, with column indices shifted by n.
    for i in 0..n {
        let (idx, val) = at.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            indices.push(j + n as u32);
            values.push(v);
        }
        indptr.push(indices.len());
    }
    // Last m rows: [A, 0] -> A's row i, column indices unshifted.
    for i in 0..m {
        let (idx, val) = a.row(i);
        for (&j, &v) in idx.iter().zip(val) {
            indices.push(j);
            values.push(v);
        }
        indptr.push(indices.len());
    }
    Csr { rows: size, cols: size, indptr, indices, values }
}

/// Connected components by BFS; returns (component id per vertex, count).
pub fn connected_components(adj: &Csr) -> (Vec<usize>, usize) {
    assert_eq!(adj.rows, adj.cols);
    let n = adj.rows;
    let mut comp = vec![usize::MAX; n];
    let mut count = 0;
    let mut queue = std::collections::VecDeque::new();
    for start in 0..n {
        if comp[start] != usize::MAX {
            continue;
        }
        comp[start] = count;
        queue.push_back(start);
        while let Some(u) = queue.pop_front() {
            let (idx, _) = adj.row(u);
            for &v in idx {
                let v = v as usize;
                if comp[v] == usize::MAX {
                    comp[v] = count;
                    queue.push_back(v);
                }
            }
        }
        count += 1;
    }
    (comp, count)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigh::jacobi_eigh;
    use crate::sparse::coo::Coo;
    use crate::testing::gen::random_edges;
    use crate::testing::prop::{check, forall};

    fn path_graph(n: usize) -> Csr {
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        Csr::from_coo(&Coo::from_undirected_edges(n, &edges))
    }

    #[test]
    fn normalized_adjacency_spectrum_in_unit_interval() {
        forall(
            41,
            8,
            |r| random_edges(r, 16, 4.0),
            |edges| {
                let a = Csr::from_coo(&Coo::from_undirected_edges(16, edges));
                let na = normalized_adjacency(&a);
                check(na.is_symmetric(1e-12), "normalized adjacency symmetric")?;
                let (lam, _) = jacobi_eigh(&na.to_dense());
                for &l in &lam {
                    check(l <= 1.0 + 1e-9 && l >= -1.0 - 1e-9, format!("eig {l} outside [-1,1]"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn normalized_adjacency_leading_eig_is_one_when_connected() {
        let a = path_graph(8);
        let na = normalized_adjacency(&a);
        let (lam, _) = jacobi_eigh(&na.to_dense());
        assert!((lam[0] - 1.0).abs() < 1e-10, "leading eig {}", lam[0]);
    }

    #[test]
    fn random_walk_rows_sum_to_one() {
        let a = path_graph(6);
        let rw = random_walk_matrix(&a);
        for (i, s) in rw.row_sums().iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-12, "row {i} sums to {s}");
        }
    }

    #[test]
    fn laplacian_annihilates_constants() {
        let a = path_graph(7);
        let l = laplacian(&a);
        let y = l.matvec(&vec![1.0; 7]);
        assert!(y.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn normalized_laplacian_psd() {
        let mut rng = crate::util::rng::Rng::new(42);
        let edges = random_edges(&mut rng, 12, 3.0);
        let a = Csr::from_coo(&Coo::from_undirected_edges(12, &edges));
        let nl = normalized_laplacian(&a);
        let (lam, _) = jacobi_eigh(&nl.to_dense());
        assert!(lam.iter().all(|&l| l >= -1e-9 && l <= 2.0 + 1e-9));
    }

    #[test]
    fn dilation_structure_and_spectrum() {
        // A = [[1, 0], [0, 2], [3, 0]] (3x2): singular values {3.16..., 2}
        let mut c = Coo::new(3, 2);
        c.push(0, 0, 1.0);
        c.push(1, 1, 2.0);
        c.push(2, 0, 3.0);
        let a = Csr::from_coo(&c);
        let s = dilation(&a);
        assert_eq!(s.rows, 5);
        assert!(s.is_symmetric(1e-14));
        let (lam, _) = jacobi_eigh(&s.to_dense());
        // Eigenvalues: ±sigma plus one zero (m - n = 1).
        let sig1 = 10.0f64.sqrt();
        assert!((lam[0] - sig1).abs() < 1e-10);
        assert!((lam[1] - 2.0).abs() < 1e-10);
        assert!(lam[2].abs() < 1e-10);
        assert!((lam[3] + 2.0).abs() < 1e-10);
        assert!((lam[4] + sig1).abs() < 1e-10);
    }

    #[test]
    fn dilation_spectrum_symmetric_property() {
        forall(
            43,
            8,
            |r| {
                let m = 2 + r.below(5);
                let n = 2 + r.below(5);
                let mut c = Coo::new(m, n);
                for _ in 0..(m * n / 2).max(1) {
                    c.push(r.below(m), r.below(n), r.normal());
                }
                c
            },
            |coo| {
                let a = Csr::from_coo(coo);
                let s = dilation(&a);
                let (lam, _) = jacobi_eigh(&s.to_dense());
                // lam sorted desc; spectrum must be symmetric about 0.
                let k = lam.len();
                for i in 0..k {
                    check(
                        (lam[i] + lam[k - 1 - i]).abs() < 1e-9,
                        format!("spectrum not symmetric: {} vs {}", lam[i], lam[k - 1 - i]),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn connected_components_counts() {
        // Two triangles, one isolated vertex.
        let edges = [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)];
        let a = Csr::from_coo(&Coo::from_undirected_edges(7, &edges));
        let (comp, count) = connected_components(&a);
        assert_eq!(count, 3);
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[3], comp[5]);
        assert_ne!(comp[0], comp[3]);
        assert_ne!(comp[6], comp[0]);
    }

    #[test]
    fn isolated_vertices_zero_rows() {
        let a = Csr::from_coo(&Coo::from_undirected_edges(4, &[(0, 1)]));
        let na = normalized_adjacency(&a);
        let (idx, _) = na.row(3);
        assert!(idx.is_empty());
    }
}
