//! Sparse-matrix substrate: the scalable operator FastEmbed iterates.
//!
//! * [`coo`] — coordinate-format builder (what generators and I/O produce).
//! * [`csr`] — compressed sparse row with the multi-vector product
//!   (`SpMM`) that dominates the algorithm's runtime.
//! * [`sellcs`] — SELL-C-σ (sliced ELLPACK) storage, the alternate SpMM
//!   backend for skewed degree distributions; bitwise-identical output.
//! * [`tune`] — one-shot runtime kernel autotuner (lane width ×
//!   row-block budget × format, measured on the actual matrix).
//! * [`graph`] — graph-derived operators: degrees, normalized adjacency
//!   `D^{-1/2} A D^{-1/2}`, random-walk matrix, Laplacians, and the
//!   symmetric dilation `[[0, A^T], [A, 0]]` used to embed general
//!   (rectangular) matrices (paper §3.5).
//! * [`gen`] — synthetic workload generators (SBM, Erdős–Rényi,
//!   Barabási–Albert, k-NN point-cloud graphs) standing in for the SNAP
//!   datasets (see DESIGN.md §3 Substitutions).
//! * [`io`] — SNAP-style edge-list text I/O.
//!
//! [`SparseMat`] lifts the format choice behind one type implementing
//! `embed::op::Operator`, so FastEmbed, Lanczos, filtered simultaneous
//! iteration, and the coordinator shard workers are format-agnostic.

pub mod coo;
pub mod csr;
pub mod gen;
pub mod graph;
pub mod io;
pub mod sellcs;
#[cfg(feature = "simd")]
pub mod simd;
pub mod tune;

pub use coo::Coo;
pub use csr::{Csr, CsrError, KernelCfg};
pub use sellcs::SellCs;

/// `--format auto` picks SELL-C-σ when the degree distribution's
/// coefficient of variation (σ/μ) crosses this threshold: power-law
/// graphs sit well above 1, uniform-degree SBM/k-NN graphs well below.
pub const AUTO_DEGREE_CV: f64 = 0.75;

/// Requested storage format (`--format csr|sell|auto`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FormatChoice {
    Csr,
    Sell,
    Auto,
}

impl FormatChoice {
    pub fn parse(s: &str) -> Result<FormatChoice, String> {
        match s.to_ascii_lowercase().as_str() {
            "csr" => Ok(FormatChoice::Csr),
            "sell" => Ok(FormatChoice::Sell),
            "auto" => Ok(FormatChoice::Auto),
            other => Err(format!("--format: expected csr|sell|auto, got '{other}'")),
        }
    }
}

/// Coefficient of variation (std/mean) of the row-degree distribution —
/// the `auto` format signal. High variance means CSR's per-row lane
/// overhead dominates on the short rows and SELL-C-σ wins.
pub fn degree_cv(a: &Csr) -> f64 {
    if a.rows == 0 {
        return 0.0;
    }
    let n = a.rows as f64;
    let mean = a.nnz() as f64 / n;
    if mean == 0.0 {
        return 0.0;
    }
    let var = (0..a.rows)
        .map(|i| {
            let dev = (a.indptr[i + 1] - a.indptr[i]) as f64 - mean;
            dev * dev
        })
        .sum::<f64>()
        / n;
    var.sqrt() / mean
}

/// A sparse matrix behind a storage-format choice, carrying the kernel
/// configuration the autotuner picked (defaults otherwise). Every
/// backend produces bitwise-identical products, so callers can treat
/// the choice as pure performance policy.
#[derive(Clone, Debug)]
pub enum SparseMat {
    /// Row-ordered CSR — the ingestion format and uniform-degree default.
    Csr { mat: Csr, cfg: KernelCfg },
    /// SELL-C-σ — wins on skewed (power-law) degree distributions.
    Sell { mat: SellCs, cfg: KernelCfg },
}

impl SparseMat {
    /// Wrap a CSR matrix with default kernel configuration.
    pub fn csr(mat: Csr) -> SparseMat {
        SparseMat::Csr { mat, cfg: KernelCfg::default() }
    }

    /// Resolve a format choice: `Auto` measures [`degree_cv`] against
    /// [`AUTO_DEGREE_CV`]. SELL packing failures (u32 overflow) cannot
    /// occur for matrices that passed CSR ingestion, but are surfaced
    /// typed rather than panicking.
    pub fn build(mat: Csr, choice: FormatChoice, cfg: KernelCfg) -> Result<SparseMat, CsrError> {
        let use_sell = match choice {
            FormatChoice::Csr => false,
            FormatChoice::Sell => true,
            FormatChoice::Auto => degree_cv(&mat) >= AUTO_DEGREE_CV,
        };
        if use_sell {
            Ok(SparseMat::Sell { mat: SellCs::from_csr_default(&mat)?, cfg })
        } else {
            Ok(SparseMat::Csr { mat, cfg })
        }
    }

    pub fn format_name(&self) -> &'static str {
        match self {
            SparseMat::Csr { .. } => "csr",
            SparseMat::Sell { .. } => "sell-c-sigma",
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            SparseMat::Csr { mat, .. } => mat.rows,
            SparseMat::Sell { mat, .. } => mat.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            SparseMat::Csr { mat, .. } => mat.cols,
            SparseMat::Sell { mat, .. } => mat.cols,
        }
    }

    /// True nonzero count (SELL padding excluded).
    pub fn nnz(&self) -> usize {
        match self {
            SparseMat::Csr { mat, .. } => mat.nnz(),
            SparseMat::Sell { mat, .. } => mat.nnz(),
        }
    }

    pub fn mem_bytes(&self) -> usize {
        match self {
            SparseMat::Csr { mat, .. } => mat.mem_bytes(),
            SparseMat::Sell { mat, .. } => mat.mem_bytes(),
        }
    }

    pub fn cfg(&self) -> KernelCfg {
        match self {
            SparseMat::Csr { cfg, .. } | SparseMat::Sell { cfg, .. } => *cfg,
        }
    }

    /// NUMA first-touch placement of the backend's arrays
    /// ([`Csr::place`] / [`SellCs::place`]): parallel workers re-touch
    /// the pages of the partition ranges they will later compute, so
    /// under first-touch paging the operator's data lands node-local.
    /// Bitwise-invisible — pure memory-locality policy.
    pub fn place(&mut self, exec: &crate::par::ExecPolicy) {
        match self {
            SparseMat::Csr { mat, .. } => mat.place(exec),
            SparseMat::Sell { mat, .. } => mat.place(exec),
        }
    }

    /// Y = A X with the backend's kernels and tuned configuration.
    pub fn spmm_into_ws(
        &self,
        x: &crate::linalg::Mat,
        y: &mut crate::linalg::Mat,
        exec: &crate::par::ExecPolicy,
        ws: &mut crate::par::Workspace,
    ) {
        match self {
            SparseMat::Csr { mat, cfg } => mat.spmm_into_ws_cfg(x, y, exec, ws, *cfg),
            SparseMat::Sell { mat, cfg } => mat.spmm_into_ws_cfg(x, y, exec, ws, *cfg),
        }
    }

    /// Fused `y = alpha·(A·x) + beta·z` with the backend's kernels.
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_axpby_into_ws(
        &self,
        x: &crate::linalg::Mat,
        alpha: f64,
        beta: f64,
        z: &crate::linalg::Mat,
        y: &mut crate::linalg::Mat,
        exec: &crate::par::ExecPolicy,
        ws: &mut crate::par::Workspace,
    ) {
        match self {
            SparseMat::Csr { mat, cfg } => {
                mat.spmm_axpby_into_ws_cfg(x, alpha, beta, z, y, exec, ws, *cfg)
            }
            SparseMat::Sell { mat, cfg } => {
                mat.spmm_axpby_into_ws_cfg(x, alpha, beta, z, y, exec, ws, *cfg)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn auto_format_picks_sell_for_power_law_and_csr_for_uniform() {
        let mut rng = Rng::new(905);
        let pl = gen::barabasi_albert(&mut rng, 400, 3);
        assert!(degree_cv(&pl.adj) >= AUTO_DEGREE_CV, "BA graph should be skewed");
        let m = SparseMat::build(pl.adj, FormatChoice::Auto, KernelCfg::default()).unwrap();
        assert_eq!(m.format_name(), "sell-c-sigma");

        let uni = gen::sbm_by_degree(&mut rng, 300, 3, 8.0, 0.8);
        assert!(degree_cv(&uni.adj) < AUTO_DEGREE_CV, "SBM graph should be uniform");
        let m = SparseMat::build(uni.adj, FormatChoice::Auto, KernelCfg::default()).unwrap();
        assert_eq!(m.format_name(), "csr");
    }

    #[test]
    fn explicit_choices_are_honored() {
        let mut rng = Rng::new(906);
        let g = gen::erdos_renyi(&mut rng, 60, 200);
        let csr = SparseMat::build(g.adj.clone(), FormatChoice::Csr, KernelCfg::default()).unwrap();
        assert_eq!(csr.format_name(), "csr");
        let sell =
            SparseMat::build(g.adj.clone(), FormatChoice::Sell, KernelCfg::default()).unwrap();
        assert_eq!(sell.format_name(), "sell-c-sigma");
        assert_eq!(sell.nnz(), csr.nnz());
        assert_eq!(sell.rows(), csr.rows());
        assert!(FormatChoice::parse("SELL").is_ok());
        assert!(FormatChoice::parse("ell").is_err());
    }

    #[test]
    fn degree_cv_edge_cases() {
        let empty = Csr::from_coo(&Coo::new(0, 0));
        assert_eq!(degree_cv(&empty), 0.0);
        let no_edges = Csr::from_coo(&Coo::new(5, 5));
        assert_eq!(degree_cv(&no_edges), 0.0);
        let eye = Csr::eye(8);
        assert_eq!(degree_cv(&eye), 0.0);
    }
}
