//! Sparse-matrix substrate: the scalable operator FastEmbed iterates.
//!
//! * [`coo`] — coordinate-format builder (what generators and I/O produce).
//! * [`csr`] — compressed sparse row with the multi-vector product
//!   (`SpMM`) that dominates the algorithm's runtime.
//! * [`graph`] — graph-derived operators: degrees, normalized adjacency
//!   `D^{-1/2} A D^{-1/2}`, random-walk matrix, Laplacians, and the
//!   symmetric dilation `[[0, A^T], [A, 0]]` used to embed general
//!   (rectangular) matrices (paper §3.5).
//! * [`gen`] — synthetic workload generators (SBM, Erdős–Rényi,
//!   Barabási–Albert, k-NN point-cloud graphs) standing in for the SNAP
//!   datasets (see DESIGN.md §3 Substitutions).
//! * [`io`] — SNAP-style edge-list text I/O.

pub mod coo;
pub mod csr;
pub mod gen;
pub mod graph;
pub mod io;

pub use coo::Coo;
pub use csr::Csr;
