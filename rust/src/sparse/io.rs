//! SNAP-style edge-list text I/O.
//!
//! Format: one `u v` pair per line, `#`-prefixed comment lines ignored —
//! the format of the SNAP datasets the paper evaluates on, so real DBLP/
//! Amazon files drop in directly when available.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use super::coo::Coo;
use super::csr::Csr;

/// Read an undirected graph from a SNAP edge-list file. Vertex ids are
/// compacted to 0..n (SNAP files have gaps); returns (adjacency, id map
/// original -> compact).
pub fn read_edge_list(path: &Path) -> std::io::Result<(Csr, Vec<u64>)> {
    let f = std::fs::File::open(path)?;
    let reader = BufReader::new(f);
    let mut remap = std::collections::HashMap::new();
    let mut ids: Vec<u64> = Vec::new();
    let mut edges: Vec<(usize, usize)> = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => continue,
        };
        let parse = |s: &str| -> Option<u64> { s.parse().ok() };
        let (Some(u), Some(v)) = (parse(a), parse(b)) else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad edge line: {line}"),
            ));
        };
        let mut intern = |x: u64| -> usize {
            *remap.entry(x).or_insert_with(|| {
                ids.push(x);
                ids.len() - 1
            })
        };
        let ui = intern(u);
        let vi = intern(v);
        if ui == vi {
            continue; // drop self loops
        }
        let key = (ui.min(vi), ui.max(vi));
        if seen.insert(key) {
            edges.push(key);
        }
    }
    let n = ids.len();
    Ok((Csr::from_coo(&Coo::from_undirected_edges(n, &edges)), ids))
}

/// Write an adjacency matrix as an edge list (upper triangle only).
pub fn write_edge_list(path: &Path, adj: &Csr, header: &str) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    if !header.is_empty() {
        for line in header.lines() {
            writeln!(f, "# {line}")?;
        }
    }
    for i in 0..adj.rows {
        let (idx, _) = adj.row(i);
        for &j in idx {
            let j = j as usize;
            if j > i {
                writeln!(f, "{i}\t{j}")?;
            }
        }
    }
    Ok(())
}

/// Write a dense embedding as TSV (one row per vertex) — consumed by the
/// bench harness and external plotting.
pub fn write_tsv(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join("\t"))?;
    for r in rows {
        let line: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
        writeln!(f, "{}", line.join("\t"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::erdos_renyi;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_edge_list() {
        let mut rng = Rng::new(61);
        let g = erdos_renyi(&mut rng, 50, 120);
        let dir = std::env::temp_dir().join("cse_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_edge_list(&path, &g.adj, "test graph").unwrap();
        let (back, ids) = read_edge_list(&path).unwrap();
        assert_eq!(back.nnz(), g.adj.nnz());
        assert!(ids.len() <= 50);
        // Same degree multiset (vertex order may differ through remap).
        let mut d1 = g.adj.row_sums();
        let mut d2 = back.row_sums();
        d1.retain(|&d| d > 0.0);
        d1.sort_by(|a, b| a.partial_cmp(b).unwrap());
        d2.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(d1, d2);
    }

    #[test]
    fn comments_gaps_and_self_loops() {
        let dir = std::env::temp_dir().join("cse_io_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g2.txt");
        std::fs::write(&path, "# comment\n10 20\n20 10\n30 30\n\n20 40\n").unwrap();
        let (g, ids) = read_edge_list(&path).unwrap();
        // Vertices 10,20,30,40 -> 4 compact ids; self loop dropped;
        // duplicate edge deduped.
        assert_eq!(ids.len(), 4);
        assert_eq!(g.nnz(), 4); // 2 undirected edges
    }

    #[test]
    fn bad_line_is_error() {
        let dir = std::env::temp_dir().join("cse_io_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g3.txt");
        std::fs::write(&path, "abc def\n").unwrap();
        assert!(read_edge_list(&path).is_err());
    }
}
