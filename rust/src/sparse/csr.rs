//! Compressed sparse row matrix + the SpMM hot path.
//!
//! The block-product kernels (`spmm_into_with`, `matvec_with`,
//! `transpose_with`) are row-partitioned over [`crate::par`]'s
//! persistent worker pool: each worker owns a disjoint, contiguous range
//! of output rows (balanced by nnz), so the result is bitwise-identical
//! to the serial loop at any thread count. The policy-free methods
//! (`spmm`, `matvec`, `transpose`, …) are serial wrappers, and
//! `spmm_into_ws` is the allocation-free form iteration loops should
//! prefer (partition scratch lives in a [`Workspace`]).
//!
//! ## Kernel shape (bandwidth-oriented)
//!
//! The multi-RHS product is column-tiled: the d right-hand-side columns
//! are processed in register-blocked lanes (by default width 8, then 4,
//! then a scalar remainder; the runtime autotuner in
//! [`super::tune`] can raise the cap to 16 or lower it via
//! [`KernelCfg`]), so each nonzero's `(u32 index, f64 value)` load is
//! amortized across the whole lane and the lane accumulator lives in
//! registers for all of a row's nonzeros (the output row is written
//! exactly once per lane). Row blocks are additionally bounded by a
//! nonzero budget (also autotunable) so the CSR segment a lane sweep
//! re-reads stays cache-resident. `spmm_axpby_into_ws` fuses the
//! three-term recurrence's scale-and-subtract
//! (`y = alpha·(A·x) + beta·z`) into the same write-back, collapsing
//! three output passes into one. With the opt-in `simd` cargo feature
//! the width-8 lane uses explicit AVX2/NEON intrinsics when the host
//! supports them ([`super::simd`]); the ops and their order are the
//! same as the autovectorized path, so the bits are too.
//!
//! Determinism: tiling splits *columns* and blocking splits *rows*;
//! neither ever splits a row's nonzeros, so every output element is
//! produced by the identical float-op sequence at any tile width, block
//! boundary, or thread count.

use std::ops::Range;

/// Nonzero budget per row block in the tiled kernels: each block's CSR
/// segment (12 bytes per nonzero) stays L2-resident while the column
/// lanes sweep it repeatedly (~384 KiB of index+value traffic per sweep).
const ROW_BLOCK_NNZ: usize = 32 * 1024;

/// Default column-lane width cap: lanes of 8, then 4, then scalar. The
/// autotuner may raise it to 16 for wide-d workloads via [`KernelCfg`];
/// the cap moves lane boundaries only and can never change output bits.
pub const DEFAULT_MAX_TILE: usize = 8;

/// Kernel tuning knobs shared by the CSR and SELL-C-σ backends: the
/// column-lane width cap and the stored-entry budget per row/slice
/// block. Defaults reproduce the untuned kernels exactly; the runtime
/// autotuner ([`super::tune`]) picks alternatives by measuring the
/// actual matrix. Both knobs move loop boundaries only — no `KernelCfg`
/// can change a single output bit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelCfg {
    /// Widest column lane the cascade may use (16, 8, 4, or 1).
    pub max_tile: usize,
    /// Stored entries per cache block (one cancellation poll each).
    pub row_block_nnz: usize,
}

impl Default for KernelCfg {
    fn default() -> Self {
        KernelCfg { max_tile: DEFAULT_MAX_TILE, row_block_nnz: ROW_BLOCK_NNZ }
    }
}

/// Shared ingestion guard for every u32-indexed storage format (CSR
/// column indices, SELL-C-σ column indices and slot→row permutation):
/// dimensions beyond `u32::MAX` cannot be addressed by the packed
/// 4-byte indices, so all constructors reject them with the same typed
/// error instead of silently truncating.
pub fn ensure_u32_indexable(dim: usize) -> Result<(), CsrError> {
    if dim > u32::MAX as usize {
        return Err(CsrError::ColumnIndexOverflow { cols: dim });
    }
    Ok(())
}

use super::coo::Coo;
use crate::linalg::Mat;
use crate::par::{self, CancelToken, ExecPolicy, Workspace};

/// Why a matrix (or the COO triplets meant to build one) was rejected.
///
/// Produced by [`Csr::validate`] and [`Csr::try_from_coo`] — the
/// ingestion guards that keep malformed or non-finite data out of the
/// kernels, which assume sorted in-bounds indices and would otherwise
/// silently produce garbage (or panic mid-job) deep inside a recurrence.
#[derive(Debug, Clone, PartialEq)]
pub enum CsrError {
    /// `indptr` must have exactly `rows + 1` entries.
    IndptrShape { expected_len: usize, got_len: usize },
    /// `indptr` must start at 0 and never decrease; `row` is the first
    /// offending position.
    IndptrNotMonotone { row: usize },
    /// `indptr[rows]` must equal the number of stored entries.
    IndptrMismatch { end: usize, nnz: usize },
    /// `indices` and `values` must have the same length.
    ValueCountMismatch { indices: usize, values: usize },
    /// A stored column index is out of bounds.
    ColumnOutOfBounds { row: usize, col: usize, cols: usize },
    /// Column indices within a row must be strictly increasing
    /// (`prev == col` means a duplicate).
    ColumnsNotSorted { row: usize, prev: usize, col: usize },
    /// A stored value is NaN or infinite.
    NonFiniteValue { row: usize, col: usize },
    /// A COO triplet addresses a cell outside the matrix shape.
    EntryOutOfBounds { index: usize, row: usize, col: usize, rows: usize, cols: usize },
    /// A COO triplet carries a NaN or infinite value.
    NonFiniteEntry { index: usize, row: usize, col: usize },
    /// A dimension exceeds the `u32` index range, so packed 4-byte
    /// indices could not address it (see [`ensure_u32_indexable`]).
    ColumnIndexOverflow { cols: usize },
}

impl std::fmt::Display for CsrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsrError::IndptrShape { expected_len, got_len } => {
                write!(f, "indptr has {got_len} entries, expected {expected_len}")
            }
            CsrError::IndptrNotMonotone { row } => {
                write!(f, "indptr is not monotone non-decreasing at row {row}")
            }
            CsrError::IndptrMismatch { end, nnz } => {
                write!(f, "indptr ends at {end} but the matrix stores {nnz} entries")
            }
            CsrError::ValueCountMismatch { indices, values } => {
                write!(f, "{indices} column indices but {values} values")
            }
            CsrError::ColumnOutOfBounds { row, col, cols } => {
                write!(f, "row {row} stores column {col}, out of bounds for {cols} columns")
            }
            CsrError::ColumnsNotSorted { row, prev, col } => write!(
                f,
                "row {row} columns are not strictly increasing ({prev} then {col})"
            ),
            CsrError::NonFiniteValue { row, col } => {
                write!(f, "non-finite value at ({row}, {col})")
            }
            CsrError::EntryOutOfBounds { index, row, col, rows, cols } => write!(
                f,
                "COO entry {index} addresses ({row}, {col}), out of bounds for {rows}x{cols}"
            ),
            CsrError::NonFiniteEntry { index, row, col } => {
                write!(f, "COO entry {index} at ({row}, {col}) is non-finite")
            }
            CsrError::ColumnIndexOverflow { cols } => {
                write!(f, "dimension {cols} exceeds the u32 index range")
            }
        }
    }
}

impl std::error::Error for CsrError {}

/// CSR sparse matrix (`f64` values).
#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointer, length `rows + 1`.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz, sorted within each row.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from COO, summing duplicates and sorting row segments.
    /// Panics (with the rendered [`CsrError`]) on out-of-bounds or
    /// non-finite triplets — use [`Self::try_from_coo`] at ingestion
    /// boundaries where malformed input is survivable.
    pub fn from_coo(coo: &Coo) -> Csr {
        Self::try_from_coo(coo).unwrap_or_else(|e| panic!("invalid COO input: {e}"))
    }

    /// Fallible [`Self::from_coo`]: rejects triplets that address cells
    /// outside `rows × cols` or carry NaN/infinite values, with a typed
    /// error naming the first offender. Duplicates remain legal (they
    /// are summed).
    pub fn try_from_coo(coo: &Coo) -> Result<Csr, CsrError> {
        ensure_u32_indexable(coo.cols)?;
        for (k, &(i, j, v)) in coo.entries.iter().enumerate() {
            if i >= coo.rows || j >= coo.cols {
                return Err(CsrError::EntryOutOfBounds {
                    index: k,
                    row: i,
                    col: j,
                    rows: coo.rows,
                    cols: coo.cols,
                });
            }
            if !v.is_finite() {
                return Err(CsrError::NonFiniteEntry { index: k, row: i, col: j });
            }
        }
        Ok(Self::from_coo_unchecked(coo))
    }

    fn from_coo_unchecked(coo: &Coo) -> Csr {
        let mut counts = vec![0usize; coo.rows + 1];
        for &(i, _, _) in &coo.entries {
            counts[i + 1] += 1;
        }
        for i in 0..coo.rows {
            counts[i + 1] += counts[i];
        }
        let indptr_raw = counts.clone();
        let mut idx = vec![0u32; coo.nnz()];
        let mut val = vec![0.0; coo.nnz()];
        let mut cursor = indptr_raw.clone();
        for &(i, j, v) in &coo.entries {
            let p = cursor[i];
            idx[p] = j as u32;
            val[p] = v;
            cursor[i] += 1;
        }
        // Sort each row segment by column, then merge duplicates.
        let mut indptr = vec![0usize; coo.rows + 1];
        let mut out_idx = Vec::with_capacity(coo.nnz());
        let mut out_val = Vec::with_capacity(coo.nnz());
        for i in 0..coo.rows {
            let (s, e) = (indptr_raw[i], indptr_raw[i + 1]);
            let mut seg: Vec<(u32, f64)> =
                idx[s..e].iter().copied().zip(val[s..e].iter().copied()).collect();
            seg.sort_by_key(|&(j, _)| j);
            let mut k = 0;
            while k < seg.len() {
                let j = seg[k].0;
                let mut v = 0.0;
                while k < seg.len() && seg[k].0 == j {
                    v += seg[k].1;
                    k += 1;
                }
                if v != 0.0 {
                    out_idx.push(j);
                    out_val.push(v);
                }
            }
            indptr[i + 1] = out_idx.len();
        }
        Csr {
            rows: coo.rows,
            cols: coo.cols,
            indptr,
            indices: out_idx,
            values: out_val,
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Csr {
        Csr {
            rows: n,
            cols: n,
            indptr: (0..=n).collect(),
            indices: (0..n as u32).collect(),
            values: vec![1.0; n],
        }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Check every structural and numerical invariant the kernels rely
    /// on: `indptr` shaped `rows + 1`, starting at 0, monotone, ending
    /// at nnz; matching index/value lengths; strictly increasing
    /// in-bounds column indices per row; finite values. `O(nnz)` — run
    /// it once at ingestion, not per product.
    pub fn validate(&self) -> Result<(), CsrError> {
        ensure_u32_indexable(self.cols)?;
        if self.indptr.len() != self.rows + 1 {
            return Err(CsrError::IndptrShape {
                expected_len: self.rows + 1,
                got_len: self.indptr.len(),
            });
        }
        if self.indptr[0] != 0 {
            return Err(CsrError::IndptrNotMonotone { row: 0 });
        }
        for i in 0..self.rows {
            if self.indptr[i + 1] < self.indptr[i] {
                return Err(CsrError::IndptrNotMonotone { row: i });
            }
        }
        if self.indices.len() != self.values.len() {
            return Err(CsrError::ValueCountMismatch {
                indices: self.indices.len(),
                values: self.values.len(),
            });
        }
        if self.indptr[self.rows] != self.indices.len() {
            return Err(CsrError::IndptrMismatch {
                end: self.indptr[self.rows],
                nnz: self.indices.len(),
            });
        }
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            let mut prev: Option<u32> = None;
            for (&j, &v) in idx.iter().zip(val) {
                if j as usize >= self.cols {
                    return Err(CsrError::ColumnOutOfBounds {
                        row: i,
                        col: j as usize,
                        cols: self.cols,
                    });
                }
                if let Some(p) = prev {
                    if j <= p {
                        return Err(CsrError::ColumnsNotSorted {
                            row: i,
                            prev: p as usize,
                            col: j as usize,
                        });
                    }
                }
                if !v.is_finite() {
                    return Err(CsrError::NonFiniteValue { row: i, col: j as usize });
                }
                prev = Some(j);
            }
        }
        Ok(())
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }

    /// y = A x (single vector) — the serial wrapper over the d = 1 SpMM
    /// kernel (one kernel to maintain, one place to parallelize).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.matvec_with(x, &ExecPolicy::serial())
    }

    /// y = A x with row-partitioned threading. Bitwise-identical to
    /// [`Self::matvec`] at any thread count.
    pub fn matvec_with(&self, x: &[f64], exec: &ExecPolicy) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        let cfg = KernelCfg::default();
        if exec.is_serial() {
            self.spmm_rows(x, 1, 0..self.rows, &mut y, cfg, None);
            return y;
        }
        let ranges = par::weighted_ranges(&self.indptr, exec.chunks(self.rows));
        exec.for_chunks(&ranges, &mut y, 1, |_, rows, chunk| {
            self.spmm_rows(x, 1, rows, chunk, cfg, None)
        });
        y
    }

    /// Y = A X — the FastEmbed hot path (serial wrapper). X row-major
    /// (cols = d) so the inner loop streams d contiguous floats per
    /// non-zero: the paper's "parallel across starting vectors" becomes
    /// SIMD/cache-level parallelism within a row, and `_with` variants
    /// add row-range parallelism across cores on top.
    pub fn spmm(&self, x: &Mat) -> Mat {
        self.spmm_with(x, &ExecPolicy::serial())
    }

    /// Y = A X with row-partitioned threading.
    pub fn spmm_with(&self, x: &Mat, exec: &ExecPolicy) -> Mat {
        let mut y = Mat::zeros(self.rows, x.cols);
        self.spmm_into_with(x, &mut y, exec);
        y
    }

    /// SpMM into a preallocated output (hot loop avoids allocation;
    /// serial wrapper).
    pub fn spmm_into(&self, x: &Mat, y: &mut Mat) {
        self.spmm_into_with(x, y, &ExecPolicy::serial());
    }

    /// SpMM into a preallocated output, output rows partitioned across
    /// `exec.threads` workers balanced by nnz. Each worker owns a
    /// disjoint row range, so the result is bitwise-identical to the
    /// serial kernel at any thread count.
    pub fn spmm_into_with(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
        let mut ws = Workspace::new();
        self.spmm_into_ws(x, y, exec, &mut ws);
    }

    /// [`Self::spmm_into_with`] with partition scratch drawn from `ws` —
    /// the steady-state form: called in a loop with the same workspace it
    /// performs zero heap allocations per product at any thread count
    /// (the serial path allocates nothing to begin with).
    pub fn spmm_into_ws(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy, ws: &mut Workspace) {
        self.spmm_into_ws_cfg(x, y, exec, ws, KernelCfg::default());
    }

    /// [`Self::spmm_into_ws`] with an explicit kernel configuration
    /// (autotuner output). `cfg` moves lane and block boundaries only —
    /// the output bits cannot change.
    pub fn spmm_into_ws_cfg(
        &self,
        x: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
        cfg: KernelCfg,
    ) {
        assert_eq!(x.rows, self.cols, "spmm shape mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        let _span = crate::obs::span(&crate::obs::SPMM);
        let d = x.cols;
        // Cloning an `Option<CancelToken>` is free for `None` (the
        // default) and one atomic refcount bump otherwise — never an
        // allocation, so the warm-workspace zero-alloc contract holds.
        let cancel = ws.cancel.clone();
        if exec.is_serial() {
            // Allocation-free serial path (the recursion's default): one
            // whole-matrix chunk, no partitioning.
            self.spmm_rows(&x.data, d, 0..self.rows, &mut y.data, cfg, cancel.as_ref());
            return;
        }
        let mut ranges = std::mem::take(&mut ws.ranges);
        par::weighted_ranges_sticky(
            &self.indptr,
            exec.chunks(self.rows),
            &mut ranges,
            &mut ws.ranges_key,
        );
        exec.for_chunks(&ranges, &mut y.data, d, |_, rows, chunk| {
            self.spmm_rows(&x.data, d, rows, chunk, cfg, cancel.as_ref())
        });
        ws.ranges = ranges;
    }

    /// Fused SpMM-axpby: `y = alpha·(A·x) + beta·z` in a single pass over
    /// the output (serial wrapper). `z` must have `y`'s shape; it is read
    /// only when `beta != 0`. The write-back specializes `beta == 0`
    /// (pure scaled product) and `alpha == 1 && beta == 0` (plain SpMM,
    /// bitwise-identical to [`Self::spmm_into`]).
    pub fn spmm_axpby_into(&self, x: &Mat, alpha: f64, beta: f64, z: &Mat, y: &mut Mat) {
        let mut ws = Workspace::new();
        self.spmm_axpby_into_ws(x, alpha, beta, z, y, &ExecPolicy::serial(), &mut ws);
    }

    /// [`Self::spmm_axpby_into`] with row-partitioned threading and
    /// workspace-backed partition scratch — the recurrence hot path:
    /// `apply_series_ws` calls this once per iteration instead of an
    /// SpMM plus two more full passes for the scale and the subtraction.
    /// Bitwise-identical at any thread count and any tile width.
    pub fn spmm_axpby_into_ws(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
    ) {
        self.spmm_axpby_into_ws_cfg(x, alpha, beta, z, y, exec, ws, KernelCfg::default());
    }

    /// [`Self::spmm_axpby_into_ws`] with an explicit kernel
    /// configuration (autotuner output).
    #[allow(clippy::too_many_arguments)]
    pub fn spmm_axpby_into_ws_cfg(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        exec: &ExecPolicy,
        ws: &mut Workspace,
        cfg: KernelCfg,
    ) {
        assert_eq!(x.rows, self.cols, "spmm shape mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        assert_eq!((z.rows, z.cols), (y.rows, y.cols), "z must match the output shape");
        let _span = crate::obs::span(&crate::obs::SPMM);
        let d = x.cols;
        let cancel = ws.cancel.clone();
        if exec.is_serial() {
            self.blocked_rows_fused(
                &x.data,
                d,
                0..self.rows,
                &mut y.data,
                alpha,
                beta,
                &z.data,
                cfg,
                cancel.as_ref(),
            );
            return;
        }
        let mut ranges = std::mem::take(&mut ws.ranges);
        par::weighted_ranges_sticky(
            &self.indptr,
            exec.chunks(self.rows),
            &mut ranges,
            &mut ws.ranges_key,
        );
        exec.for_chunks(&ranges, &mut y.data, d, |_, rows, chunk| {
            let zc = &z.data[rows.start * d..rows.end * d];
            self.blocked_rows_fused(&x.data, d, rows, chunk, alpha, beta, zc, cfg, cancel.as_ref());
        });
        ws.ranges = ranges;
    }

    /// Test-only entry: serial fused product with the lane width capped at
    /// `max_tile` (1 = all-scalar, 4, 8 = production), for asserting that
    /// the tile choice cannot change a single output bit.
    #[doc(hidden)]
    pub fn spmm_axpby_max_tile(
        &self,
        x: &Mat,
        alpha: f64,
        beta: f64,
        z: &Mat,
        y: &mut Mat,
        max_tile: usize,
    ) {
        assert_eq!(x.rows, self.cols, "spmm shape mismatch");
        assert_eq!((y.rows, y.cols), (self.rows, x.cols));
        assert_eq!((z.rows, z.cols), (y.rows, y.cols));
        let cfg = KernelCfg { max_tile: max_tile.max(1), ..KernelCfg::default() };
        let (rows, zd) = (0..self.rows, &z.data);
        self.blocked_rows_fused(&x.data, x.cols, rows, &mut y.data, alpha, beta, zd, cfg, None);
    }

    /// The one SpMM kernel: output rows `rows` of `A·X` written into `y`
    /// (a slice holding exactly those rows), `x` row-major with width `d`.
    /// Both the full-matrix entry points and the parallel row chunks call
    /// this, so serial and threaded execution share every float op.
    fn spmm_rows(
        &self,
        x: &[f64],
        d: usize,
        rows: Range<usize>,
        y: &mut [f64],
        cfg: KernelCfg,
        cancel: Option<&CancelToken>,
    ) {
        self.blocked_rows_fused(x, d, rows, y, 1.0, 0.0, &[], cfg, cancel);
    }

    /// Row-blocked, column-tiled fused kernel for output rows `rows`:
    /// `y = alpha·(A·x) + beta·z`, with `y` (and `z` when `beta != 0`)
    /// holding exactly those rows. Row blocks are bounded by
    /// `cfg.row_block_nnz` nonzeros (default [`ROW_BLOCK_NNZ`]) so the
    /// CSR segment the lanes re-sweep stays cache-resident; block
    /// boundaries are cache blocking only and cannot affect bits (no
    /// row's nonzeros are ever split).
    #[allow(clippy::too_many_arguments)]
    fn blocked_rows_fused(
        &self,
        x: &[f64],
        d: usize,
        rows: Range<usize>,
        y: &mut [f64],
        alpha: f64,
        beta: f64,
        z: &[f64],
        cfg: KernelCfg,
        cancel: Option<&CancelToken>,
    ) {
        debug_assert!(beta == 0.0 || z.len() == y.len());
        let mut start = rows.start;
        while start < rows.end {
            // Cancellation checkpoint: one poll per ~`cfg.row_block_nnz`
            // nonzeros. A cancelled product returns with `y` partially
            // written — the caller that observed cancellation discards
            // it, so partial state never reaches a result.
            if let Some(c) = cancel {
                if c.is_cancelled() {
                    return;
                }
            }
            let budget = self.indptr[start] + cfg.row_block_nnz;
            let mut end = start + 1;
            while end < rows.end && self.indptr[end + 1] <= budget {
                end += 1;
            }
            let lo = (start - rows.start) * d;
            let hi = (end - rows.start) * d;
            let zb = if beta != 0.0 { &z[lo..hi] } else { &z[0..0] };
            self.fused_block(x, d, start..end, &mut y[lo..hi], alpha, beta, zb, cfg.max_tile);
            start = end;
        }
    }

    /// Sweep one row block through the column-lane cascade: 16 when the
    /// autotuner raised the cap, then 8, 4, and a scalar remainder.
    /// `max_tile` caps the lane width (tests prove the cap is
    /// bitwise-invisible; the untuned default is [`DEFAULT_MAX_TILE`]).
    #[allow(clippy::too_many_arguments)]
    fn fused_block(
        &self,
        x: &[f64],
        d: usize,
        rows: Range<usize>,
        y: &mut [f64],
        alpha: f64,
        beta: f64,
        z: &[f64],
        max_tile: usize,
    ) {
        let mut c0 = 0;
        while c0 + 16 <= d && max_tile >= 16 {
            self.fused_lane::<16>(x, d, c0, rows.clone(), y, alpha, beta, z);
            c0 += 16;
        }
        while c0 + 8 <= d && max_tile >= 8 {
            self.fused_lane8(x, d, c0, rows.clone(), y, alpha, beta, z);
            c0 += 8;
        }
        while c0 + 4 <= d && max_tile >= 4 {
            self.fused_lane::<4>(x, d, c0, rows.clone(), y, alpha, beta, z);
            c0 += 4;
        }
        while c0 < d {
            self.fused_lane::<1>(x, d, c0, rows.clone(), y, alpha, beta, z);
            c0 += 1;
        }
    }

    /// The width-8 lane, with the explicit-SIMD fast path when the
    /// `simd` cargo feature is on and the host supports it (AVX2 on
    /// x86-64, NEON on aarch64). The intrinsics perform the identical
    /// multiply-then-add per element in the identical order — no FMA —
    /// so the fast path is bitwise-equal to the autovectorized one.
    #[allow(clippy::too_many_arguments)]
    fn fused_lane8(
        &self,
        x: &[f64],
        d: usize,
        c0: usize,
        rows: Range<usize>,
        y: &mut [f64],
        alpha: f64,
        beta: f64,
        z: &[f64],
    ) {
        #[cfg(feature = "simd")]
        if super::simd::lane8_fast() {
            for (local, i) in rows.clone().enumerate() {
                let (idx, val) = self.row(i);
                // SAFETY: `lane8_fast` checked the CPU feature; every
                // stored column index is in-bounds (`validate`) and
                // `c0 + 8 <= d`, so each load reads inside `x`.
                let acc: [f64; 8] = unsafe { super::simd::row_acc8(idx, val, x, d, c0) };
                let ybase = local * d + c0;
                let out: &mut [f64; 8] = (&mut y[ybase..ybase + 8]).try_into().unwrap();
                if beta != 0.0 {
                    let zr: &[f64; 8] = z[ybase..ybase + 8].try_into().unwrap();
                    for c in 0..8 {
                        out[c] = alpha * acc[c] + beta * zr[c];
                    }
                } else if alpha != 1.0 {
                    for c in 0..8 {
                        out[c] = alpha * acc[c];
                    }
                } else {
                    *out = acc;
                }
            }
            return;
        }
        self.fused_lane::<8>(x, d, c0, rows, y, alpha, beta, z);
    }

    /// One register-blocked lane: output columns `[c0, c0 + W)` of rows
    /// `rows`. The accumulator array lives in registers across all of a
    /// row's nonzeros, so each `(index, value)` pair is loaded once per
    /// lane instead of once per column, and the output is written exactly
    /// once. Per output element the float ops and their order are
    /// identical for every lane width — the bitwise-determinism contract.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    fn fused_lane<const W: usize>(
        &self,
        x: &[f64],
        d: usize,
        c0: usize,
        rows: Range<usize>,
        y: &mut [f64],
        alpha: f64,
        beta: f64,
        z: &[f64],
    ) {
        for (local, i) in rows.enumerate() {
            let (idx, val) = self.row(i);
            let mut acc = [0.0f64; W];
            for (&j, &aij) in idx.iter().zip(val) {
                let base = j as usize * d + c0;
                let xr: &[f64; W] = x[base..base + W].try_into().unwrap();
                for c in 0..W {
                    acc[c] += aij * xr[c];
                }
            }
            let ybase = local * d + c0;
            let out: &mut [f64; W] = (&mut y[ybase..ybase + W]).try_into().unwrap();
            if beta != 0.0 {
                let zr: &[f64; W] = z[ybase..ybase + W].try_into().unwrap();
                for c in 0..W {
                    out[c] = alpha * acc[c] + beta * zr[c];
                }
            } else if alpha != 1.0 {
                for c in 0..W {
                    out[c] = alpha * acc[c];
                }
            } else {
                *out = acc;
            }
        }
    }

    /// Explicit transpose (CSR -> CSR), serial wrapper.
    pub fn transpose(&self) -> Csr {
        self.transpose_with(&ExecPolicy::serial())
    }

    /// Parallel transpose. Workers own disjoint ranges of *output* rows
    /// (columns of `self`), each scanning the input and binary-searching
    /// the entries that fall in its column range, then writing the
    /// contiguous `indptr[c0]..indptr[c1]` output segment. Within a
    /// column, entries land in ascending input-row order — exactly the
    /// serial layout, so the result is bitwise-identical at any thread
    /// count.
    ///
    /// Trade-off: disjoint contiguous writes (no unsafe scatter) cost
    /// each worker an `O(rows · log deg)` scan of the row index arrays
    /// on top of its `nnz/threads` share, so the speedup is strongest
    /// for dense-ish matrices and modest at very low average degree.
    /// A cheap row-span reject skips rows that cannot intersect the
    /// worker's column range.
    pub fn transpose_with(&self, exec: &ExecPolicy) -> Csr {
        let nnz = self.nnz();
        // Pass 1: column occupancy (integer counts, so worker-local
        // accumulation + merge cannot change the result).
        let mut counts = vec![0usize; self.cols + 1];
        if exec.is_serial() || nnz == 0 {
            for &j in &self.indices {
                counts[j as usize + 1] += 1;
            }
        } else {
            let ranges = par::even_ranges(nnz, exec.threads);
            let partials = exec.map_ranges(&ranges, |_, r| {
                let mut c = vec![0usize; self.cols];
                for &j in &self.indices[r] {
                    c[j as usize] += 1;
                }
                c
            });
            for p in partials {
                for (j, v) in p.into_iter().enumerate() {
                    counts[j + 1] += v;
                }
            }
        }
        for i in 0..self.cols {
            counts[i + 1] += counts[i];
        }
        let indptr = counts;
        // Pass 2: scatter into per-worker contiguous output segments.
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0.0; nnz];
        let parts = if exec.is_serial() { 1 } else { exec.threads.min(self.cols.max(1)) };
        let col_ranges = par::weighted_ranges(&indptr, parts);
        let sizes: Vec<usize> =
            col_ranges.iter().map(|r| indptr[r.end] - indptr[r.start]).collect();
        let idx_parts = par::split_mut(&mut indices, sizes.iter().copied());
        let val_parts = par::split_mut(&mut values, sizes.iter().copied());
        let parts: Vec<(&mut [u32], &mut [f64])> =
            idx_parts.into_iter().zip(val_parts).collect();
        exec.map_parts(parts, |k, (ic, vc)| {
            let r = &col_ranges[k];
            let base = indptr[r.start];
            let mut cursor: Vec<usize> = indptr[r.start..r.end].to_vec();
            for i in 0..self.rows {
                let (idx, val) = self.row(i);
                // Row-span reject: sorted columns, so compare the ends.
                match (idx.first(), idx.last()) {
                    (Some(&f), Some(&l)) if (l as usize) >= r.start && (f as usize) < r.end => {}
                    _ => continue,
                }
                let lo = idx.partition_point(|&j| (j as usize) < r.start);
                let hi = lo + idx[lo..].partition_point(|&j| (j as usize) < r.end);
                for (&j, &v) in idx[lo..hi].iter().zip(&val[lo..hi]) {
                    let c = j as usize - r.start;
                    let p = cursor[c] - base;
                    ic[p] = i as u32;
                    vc[p] = v;
                    cursor[c] += 1;
                }
            }
        });
        Csr { rows: self.cols, cols: self.rows, indptr, indices, values }
    }

    /// NUMA first-touch placement: re-materialize the index and value
    /// arrays so each parallel worker first-touches exactly the pages
    /// backing the row range it will later compute, using the same
    /// nnz-balanced partition the SpMM kernels derive from `exec`.
    /// Under Linux's default first-touch policy those pages land on
    /// the node of the touching worker; paired with worker pinning
    /// (`par::affinity`) the operator's data stays node-local for the
    /// whole job. The contents are copied verbatim and `indptr` is
    /// left in place — placement is bitwise-invisible
    /// (`rust/tests/par_determinism.rs`), and the sticky partition key
    /// (which identifies the matrix by its `indptr` buffer) stays
    /// valid across a `place`.
    pub fn place(&mut self, exec: &ExecPolicy) {
        if self.rows == 0 || self.nnz() == 0 || exec.is_serial() {
            return;
        }
        let _span = crate::obs::span(&crate::obs::NUMA_PLACE);
        let ranges = par::weighted_ranges(&self.indptr, exec.chunks(self.rows));
        let nnz = self.nnz();
        // Fresh zeroed Vecs come from lazily-mapped pages (untouched
        // until written), so the parallel copy below is the first touch.
        let mut values = vec![0.0f64; nnz];
        let mut indices = vec![0u32; nnz];
        // Raw-pointer wrapper for the disjoint per-range writes (same
        // idiom as the pool's chunk dispatch, local to this method).
        struct SendMut<T>(*mut T);
        unsafe impl<T> Send for SendMut<T> {}
        unsafe impl<T> Sync for SendMut<T> {}
        let vp = SendMut(values.as_mut_ptr());
        let ip = SendMut(indices.as_mut_ptr());
        let ranges = &ranges;
        exec.run_indexed(ranges.len(), |k| {
            let r = &ranges[k];
            let (s, e) = (self.indptr[r.start], self.indptr[r.end]);
            // SAFETY: the partition is ascending, contiguous, and
            // covering, so `[s, e)` segments are disjoint across `k`
            // and in-bounds for all three buffers; each element is
            // written by exactly one worker and the Vecs outlive the
            // region (`run_indexed` joins before returning).
            unsafe {
                std::ptr::copy_nonoverlapping(self.values.as_ptr().add(s), vp.0.add(s), e - s);
                std::ptr::copy_nonoverlapping(self.indices.as_ptr().add(s), ip.0.add(s), e - s);
            }
        });
        self.values = values;
        self.indices = indices;
    }

    /// Dense conversion (tests / small oracles only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                m[(i, j as usize)] += v;
            }
        }
        m
    }

    /// Row sums (degrees for an adjacency matrix).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|i| self.row(i).1.iter().sum())
            .collect()
    }

    /// In-place scale of all values.
    pub fn scale(&mut self, s: f64) {
        for v in self.values.iter_mut() {
            *v *= s;
        }
    }

    /// D1 * A * D2 for diagonal matrices given as vectors (in place).
    pub fn diag_scale(&mut self, left: &[f64], right: &[f64]) {
        assert_eq!(left.len(), self.rows);
        assert_eq!(right.len(), self.cols);
        for i in 0..self.rows {
            let (s, e) = (self.indptr[i], self.indptr[i + 1]);
            for p in s..e {
                self.values[p] *= left[i] * right[self.indices[p] as usize];
            }
        }
    }

    /// Structural + numerical symmetry test.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        let t = self.transpose();
        if t.indptr != self.indptr || t.indices != self.indices {
            return false;
        }
        self.values
            .iter()
            .zip(&t.values)
            .all(|(a, b)| (a - b).abs() <= tol)
    }

    /// Memory footprint in bytes (metrics/reporting).
    pub fn mem_bytes(&self) -> usize {
        self.indptr.len() * 8 + self.indices.len() * 4 + self.values.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::gen::random_edges;
    use crate::testing::prop::{all_close, check, forall};
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, rows: usize, cols: usize, nnz: usize) -> Coo {
        let mut c = Coo::new(rows, cols);
        for _ in 0..nnz {
            c.push(rng.below(rows), rng.below(cols), rng.normal());
        }
        c
    }

    #[test]
    fn from_coo_sums_duplicates_and_sorts() {
        let mut c = Coo::new(2, 3);
        c.push(0, 2, 1.0);
        c.push(0, 0, 2.0);
        c.push(0, 2, 3.0);
        c.push(1, 1, -1.0);
        let m = Csr::from_coo(&c);
        assert_eq!(m.indptr, vec![0, 2, 3]);
        assert_eq!(m.indices, vec![0, 2, 1]);
        assert_eq!(m.values, vec![2.0, 4.0, -1.0]);
    }

    #[test]
    fn from_coo_drops_cancelled_entries() {
        let mut c = Coo::new(1, 1);
        c.push(0, 0, 1.0);
        c.push(0, 0, -1.0);
        let m = Csr::from_coo(&c);
        assert_eq!(m.nnz(), 0);
    }

    #[test]
    fn spmm_matches_dense_matmul() {
        forall(
            31,
            24,
            |r| {
                let rows = 2 + r.below(12);
                let cols = 2 + r.below(12);
                let d = 1 + r.below(6);
                let coo = random_coo(r, rows, cols, rows * 2);
                (coo, Mat::randn(r, cols, d))
            },
            |(coo, x)| {
                let a = Csr::from_coo(coo);
                let got = a.spmm(x);
                let want = a.to_dense().matmul(x);
                all_close(&got.data, &want.data, 1e-12)
            },
        );
    }

    #[test]
    fn matvec_matches_spmm_single_column() {
        let mut rng = Rng::new(32);
        let coo = random_coo(&mut rng, 10, 10, 30);
        let a = Csr::from_coo(&coo);
        let x: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        let xm = Mat::from_vec(10, 1, x.clone());
        let y1 = a.matvec(&x);
        let y2 = a.spmm(&xm);
        all_close(&y1, &y2.data, 1e-14).unwrap();
    }

    #[test]
    fn transpose_involution_and_correctness() {
        forall(
            33,
            16,
            |r| {
                let rows = 3 + r.below(8);
                let cols = 3 + r.below(8);
                random_coo(r, rows, cols, 20)
            },
            |coo| {
                let a = Csr::from_coo(coo);
                let t = a.transpose();
                let tt = t.transpose();
                check(tt.indptr == a.indptr && tt.indices == a.indices, "A^TT structure")?;
                all_close(&tt.values, &a.values, 1e-15)?;
                let ad = a.to_dense().transpose();
                all_close(&t.to_dense().data, &ad.data, 1e-15)
            },
        );
    }

    #[test]
    fn eye_behaves_as_identity() {
        let mut rng = Rng::new(34);
        let x = Mat::randn(&mut rng, 6, 3);
        let i = Csr::eye(6);
        assert!(i.spmm(&x).max_abs_diff(&x) < 1e-15);
        assert!(i.is_symmetric(0.0));
    }

    #[test]
    fn diag_scale_matches_dense() {
        let mut rng = Rng::new(35);
        let coo = random_coo(&mut rng, 5, 4, 12);
        let mut a = Csr::from_coo(&coo);
        let l: Vec<f64> = (0..5).map(|_| rng.uniform(0.5, 2.0)).collect();
        let r: Vec<f64> = (0..4).map(|_| rng.uniform(0.5, 2.0)).collect();
        let dense_before = a.to_dense();
        a.diag_scale(&l, &r);
        let d = a.to_dense();
        for i in 0..5 {
            for j in 0..4 {
                assert!((d[(i, j)] - l[i] * dense_before[(i, j)] * r[j]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn adjacency_symmetry() {
        let mut rng = Rng::new(36);
        let edges = random_edges(&mut rng, 40, 5.0);
        let a = Csr::from_coo(&Coo::from_undirected_edges(40, &edges));
        assert!(a.is_symmetric(0.0));
        assert_eq!(a.nnz(), 2 * edges.len());
    }

    #[test]
    fn spmm_into_reuses_buffer() {
        let mut rng = Rng::new(37);
        let coo = random_coo(&mut rng, 8, 8, 20);
        let a = Csr::from_coo(&coo);
        let x = Mat::randn(&mut rng, 8, 4);
        let mut y = Mat::from_vec(8, 4, vec![7.0; 32]); // dirty buffer
        a.spmm_into(&x, &mut y);
        assert!(y.max_abs_diff(&a.spmm(&x)) < 1e-15);
    }

    #[test]
    fn empty_rows_are_fine() {
        let c = Coo::new(3, 3); // all empty
        let a = Csr::from_coo(&c);
        assert_eq!(a.matvec(&[1.0, 2.0, 3.0]), vec![0.0; 3]);
    }

    #[test]
    fn spmm_into_ws_reuses_scratch_and_matches() {
        let mut rng = Rng::new(40);
        let coo = random_coo(&mut rng, 60, 60, 240);
        let a = Csr::from_coo(&coo);
        let x = Mat::randn(&mut rng, 60, 5);
        let want = a.spmm(&x);
        let mut ws = Workspace::new();
        let mut y = Mat::zeros(60, 5);
        for threads in [1usize, 2, 4] {
            let exec = ExecPolicy::with_threads(threads);
            for _ in 0..3 {
                y.data.fill(7.0);
                a.spmm_into_ws(&x, &mut y, &exec, &mut ws);
                assert_eq!(y.data, want.data, "spmm_into_ws @ {threads} threads");
            }
        }
        // Threaded calls leave their partition scratch behind for reuse.
        assert!(!ws.ranges.is_empty());
    }

    #[test]
    fn parallel_spmm_bitwise_matches_serial() {
        forall(
            38,
            10,
            |r| {
                let rows = 5 + r.below(60);
                let cols = 5 + r.below(60);
                let d = 1 + r.below(7);
                let coo = random_coo(r, rows, cols, rows * 3);
                (coo, Mat::randn(r, cols, d))
            },
            |(coo, x)| {
                let a = Csr::from_coo(coo);
                let want = a.spmm(x);
                for threads in [1usize, 2, 4] {
                    let exec = ExecPolicy::with_threads(threads);
                    let got = a.spmm_with(x, &exec);
                    check(got.data == want.data, format!("spmm differs at {threads} threads"))?;
                    let mut buf = Mat::from_vec(a.rows, x.cols, vec![3.0; a.rows * x.cols]);
                    a.spmm_into_with(x, &mut buf, &exec);
                    check(buf.data == want.data, format!("spmm_into at {threads} threads"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn spmm_axpby_matches_dense_oracle() {
        forall(
            41,
            16,
            |r| {
                let rows = 2 + r.below(40);
                let cols = 2 + r.below(40);
                // d crossing the 8/4/1 lane boundaries, incl. misaligned.
                let d = 1 + r.below(21);
                // nnz ~ rows: plenty of empty rows in the scatter.
                let coo = random_coo(r, rows, cols, rows);
                (
                    coo,
                    Mat::randn(r, cols, d),
                    Mat::randn(r, rows, d),
                    r.uniform(-2.0, 2.0),
                    r.uniform(-2.0, 2.0),
                )
            },
            |(coo, x, z, alpha, beta)| {
                let a = Csr::from_coo(coo);
                let mut y = Mat::from_vec(a.rows, x.cols, vec![9.0; a.rows * x.cols]);
                a.spmm_axpby_into(x, *alpha, *beta, z, &mut y);
                let t = a.to_dense().matmul(x);
                let want: Vec<f64> = t
                    .data
                    .iter()
                    .zip(&z.data)
                    .map(|(tv, zv)| alpha * tv + beta * zv)
                    .collect();
                all_close(&y.data, &want, 1e-10)
            },
        );
    }

    #[test]
    fn spmm_axpby_special_cases_match_plain_spmm_bitwise() {
        let mut rng = Rng::new(42);
        let coo = random_coo(&mut rng, 50, 50, 150);
        let a = Csr::from_coo(&coo);
        for d in [1usize, 3, 4, 8, 13, 16] {
            let x = Mat::randn(&mut rng, 50, d);
            let z = Mat::randn(&mut rng, 50, d);
            let plain = a.spmm(&x);
            // alpha = 1, beta = 0: exactly the plain product.
            let mut y = Mat::zeros(50, d);
            a.spmm_axpby_into(&x, 1.0, 0.0, &z, &mut y);
            assert_eq!(y.data, plain.data, "identity case d={d}");
            // beta = 0: pure scaled product, bitwise alpha·(A·x).
            a.spmm_axpby_into(&x, -0.75, 0.0, &z, &mut y);
            let want: Vec<f64> = plain.data.iter().map(|v| -0.75 * v).collect();
            assert_eq!(y.data, want, "scaled case d={d}");
            // beta = -c: y = c1·A·x − c·z, the recurrence's subtraction.
            a.spmm_axpby_into(&x, 2.0, -0.5, &z, &mut y);
            let want: Vec<f64> = plain
                .data
                .iter()
                .zip(&z.data)
                .map(|(t, zv)| 2.0 * t + (-0.5) * zv)
                .collect();
            assert_eq!(y.data, want, "fused case d={d}");
        }
    }

    #[test]
    fn tile_width_cap_cannot_change_bits() {
        let mut rng = Rng::new(43);
        let coo = random_coo(&mut rng, 70, 70, 280);
        let a = Csr::from_coo(&coo);
        for d in [1usize, 5, 8, 12, 13, 24] {
            let x = Mat::randn(&mut rng, 70, d);
            let z = Mat::randn(&mut rng, 70, d);
            let mut want = Mat::zeros(70, d);
            a.spmm_axpby_max_tile(&x, 1.3, -0.7, &z, &mut want, usize::MAX);
            for cap in [1usize, 4, 8, 16] {
                let mut y = Mat::zeros(70, d);
                a.spmm_axpby_max_tile(&x, 1.3, -0.7, &z, &mut y, cap);
                assert_eq!(y.data, want.data, "tile cap {cap} at d={d}");
            }
        }
    }

    #[test]
    fn kernel_cfg_cannot_change_bits() {
        // Any (max_tile, row_block_nnz) combination must reproduce the
        // default kernel bit-for-bit — the autotuner's safety contract.
        let mut rng = Rng::new(48);
        let coo = random_coo(&mut rng, 90, 90, 500);
        let a = Csr::from_coo(&coo);
        let d = 21;
        let x = Mat::randn(&mut rng, 90, d);
        let z = Mat::randn(&mut rng, 90, d);
        let mut want = Mat::zeros(90, d);
        let mut ws = Workspace::new();
        a.spmm_axpby_into_ws(&x, 1.1, -0.4, &z, &mut want, &ExecPolicy::serial(), &mut ws);
        for max_tile in [1usize, 4, 8, 16] {
            for row_block_nnz in [1usize, 64, 16 * 1024] {
                let cfg = KernelCfg { max_tile, row_block_nnz };
                for threads in [1usize, 3] {
                    let exec = ExecPolicy::with_threads(threads);
                    let mut y = Mat::from_vec(90, d, vec![7.0; 90 * d]);
                    a.spmm_axpby_into_ws_cfg(&x, 1.1, -0.4, &z, &mut y, &exec, &mut ws, cfg);
                    assert_eq!(y.data, want.data, "cfg {cfg:?} at {threads} threads");
                    let mut y2 = Mat::from_vec(90, d, vec![3.0; 90 * d]);
                    a.spmm_into_ws_cfg(&x, &mut y2, &exec, &mut ws, cfg);
                    assert_eq!(y2.data, a.spmm(&x).data, "plain cfg {cfg:?}");
                }
            }
        }
    }

    #[test]
    fn validate_rejects_u32_column_overflow() {
        #[cfg(target_pointer_width = "64")]
        {
            let m = Csr {
                rows: 0,
                cols: u32::MAX as usize + 1,
                indptr: vec![0],
                indices: vec![],
                values: vec![],
            };
            assert!(matches!(m.validate(), Err(CsrError::ColumnIndexOverflow { .. })));
            let c = Coo { rows: 1, cols: u32::MAX as usize + 1, entries: vec![] };
            assert!(matches!(
                Csr::try_from_coo(&c),
                Err(CsrError::ColumnIndexOverflow { .. })
            ));
        }
    }

    #[test]
    fn fused_kernel_handles_empty_rows_and_threads() {
        // Deliberate empty rows: the fused result there must be exactly
        // alpha·0 + beta·z, and bitwise equal across thread counts.
        let mut rng = Rng::new(44);
        let mut coo = Coo::new(40, 40);
        for _ in 0..60 {
            let i = rng.below(20) * 2; // even rows only: odd rows empty
            coo.push(i, rng.below(40), rng.normal());
        }
        let a = Csr::from_coo(&coo);
        let d = 13;
        let x = Mat::randn(&mut rng, 40, d);
        let z = Mat::randn(&mut rng, 40, d);
        let mut want = Mat::zeros(40, d);
        a.spmm_axpby_into(&x, 0.5, 2.0, &z, &mut want);
        for i in (1..40).step_by(2) {
            for c in 0..d {
                assert_eq!(want[(i, c)], 0.5 * 0.0 + 2.0 * z[(i, c)], "empty row {i}");
            }
        }
        let mut ws = Workspace::new();
        for threads in [2usize, 4] {
            let exec = ExecPolicy::with_threads(threads);
            let mut y = Mat::from_vec(40, d, vec![5.0; 40 * d]);
            a.spmm_axpby_into_ws(&x, 0.5, 2.0, &z, &mut y, &exec, &mut ws);
            assert_eq!(y.data, want.data, "{threads} threads");
        }
    }

    #[test]
    fn try_from_coo_rejects_malformed_triplets() {
        // Constructed directly: `Coo::push` debug-asserts bounds, and
        // these tests exist precisely for data that bypassed it.
        let oob_row = Coo { rows: 2, cols: 2, entries: vec![(2, 0, 1.0)] };
        assert!(matches!(
            Csr::try_from_coo(&oob_row),
            Err(CsrError::EntryOutOfBounds { index: 0, row: 2, .. })
        ));
        let oob_col = Coo { rows: 2, cols: 2, entries: vec![(0, 0, 1.0), (1, 5, 1.0)] };
        assert!(matches!(
            Csr::try_from_coo(&oob_col),
            Err(CsrError::EntryOutOfBounds { index: 1, col: 5, .. })
        ));
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let c = Coo { rows: 2, cols: 2, entries: vec![(1, 1, bad)] };
            assert!(matches!(
                Csr::try_from_coo(&c),
                Err(CsrError::NonFiniteEntry { index: 0, row: 1, col: 1 })
            ));
        }
        // Duplicates stay legal — they sum.
        let dup = Coo { rows: 1, cols: 1, entries: vec![(0, 0, 1.0), (0, 0, 2.0)] };
        assert_eq!(Csr::try_from_coo(&dup).unwrap().values, vec![3.0]);
    }

    #[test]
    fn validate_accepts_well_formed_matrices() {
        let mut rng = Rng::new(45);
        for _ in 0..20 {
            let coo = random_coo(&mut rng, 1 + rng.below(30), 1 + rng.below(30), 60);
            Csr::from_coo(&coo).validate().unwrap();
        }
        Csr::eye(7).validate().unwrap();
        Csr::from_coo(&Coo::new(4, 4)).validate().unwrap(); // all rows empty
        Csr::from_coo(&Coo::new(0, 0)).validate().unwrap();
    }

    #[test]
    fn validate_catches_each_corruption_class() {
        let base = Csr::from_coo(&Coo {
            rows: 3,
            cols: 4,
            entries: vec![(0, 1, 1.0), (0, 3, 2.0), (1, 0, -1.0), (2, 2, 0.5)],
        });
        base.validate().unwrap();

        let mut m = base.clone();
        m.indptr.pop();
        assert!(matches!(m.validate(), Err(CsrError::IndptrShape { .. })));

        let mut m = base.clone();
        m.indptr[1] = 3;
        m.indptr[2] = 2; // decreasing
        assert!(matches!(m.validate(), Err(CsrError::IndptrNotMonotone { row: 1 })));

        let mut m = base.clone();
        *m.indptr.last_mut().unwrap() += 1;
        assert!(matches!(m.validate(), Err(CsrError::IndptrMismatch { .. })));

        let mut m = base.clone();
        m.values.pop();
        assert!(matches!(m.validate(), Err(CsrError::ValueCountMismatch { .. })));

        let mut m = base.clone();
        m.indices[3] = 9; // row 2 stores column 9 of 4
        assert!(matches!(
            m.validate(),
            Err(CsrError::ColumnOutOfBounds { row: 2, col: 9, cols: 4 })
        ));

        let mut m = base.clone();
        m.indices.swap(0, 1); // row 0 now [3, 1]: unsorted
        assert!(matches!(m.validate(), Err(CsrError::ColumnsNotSorted { row: 0, .. })));

        let mut m = base.clone();
        m.indices[1] = m.indices[0]; // duplicate column in row 0
        assert!(matches!(m.validate(), Err(CsrError::ColumnsNotSorted { row: 0, .. })));

        let mut m = base.clone();
        m.values[2] = f64::NAN;
        assert!(matches!(m.validate(), Err(CsrError::NonFiniteValue { row: 1, col: 0 })));
    }

    #[test]
    fn validate_fuzz_rejects_random_corruptions() {
        let mut rng = Rng::new(46);
        for trial in 0..50 {
            let rows = 2 + rng.below(20);
            let cols = 2 + rng.below(20);
            let coo = random_coo(&mut rng, rows, cols, 3 * rows);
            let mut m = Csr::from_coo(&coo);
            if m.nnz() == 0 {
                continue;
            }
            let k = rng.below(m.nnz());
            match rng.below(4) {
                0 => m.indices[k] = (cols + rng.below(5)) as u32,
                1 => m.values[k] = f64::NAN,
                2 => {
                    m.indptr.truncate(rows); // wrong length
                }
                _ => {
                    // Force a strict-ordering violation inside some row
                    // by duplicating its first stored column.
                    let row = m.indptr.partition_point(|&p| p <= k) - 1;
                    let (s, e) = (m.indptr[row], m.indptr[row + 1]);
                    if e - s < 2 {
                        m.indices[k] = (cols + 1) as u32; // fall back to OOB
                    } else {
                        let first = m.indices[s];
                        m.indices[s + 1] = first;
                    }
                }
            }
            assert!(m.validate().is_err(), "trial {trial}: corruption went undetected");
        }
    }

    #[test]
    fn cancelled_workspace_aborts_spmm_before_writing() {
        use crate::par::CancelToken;
        let mut rng = Rng::new(47);
        let coo = random_coo(&mut rng, 30, 30, 90);
        let a = Csr::from_coo(&coo);
        let x = Mat::randn(&mut rng, 30, 4);
        let z = Mat::randn(&mut rng, 30, 4);
        let token = CancelToken::new();
        token.cancel();
        let mut ws = Workspace::new();
        ws.cancel = Some(token);
        for threads in [1usize, 3] {
            let exec = ExecPolicy::with_threads(threads);
            let mut y = Mat::from_vec(30, 4, vec![7.0; 120]);
            a.spmm_into_ws(&x, &mut y, &exec, &mut ws);
            assert!(y.data.iter().all(|&v| v == 7.0), "cancelled spmm must not write");
            a.spmm_axpby_into_ws(&x, 2.0, -1.0, &z, &mut y, &exec, &mut ws);
            assert!(y.data.iter().all(|&v| v == 7.0), "cancelled fused spmm must not write");
        }
        // Clearing the token restores normal operation with the same ws.
        ws.cancel = None;
        let mut y = Mat::zeros(30, 4);
        a.spmm_into_ws(&x, &mut y, &ExecPolicy::serial(), &mut ws);
        assert_eq!(y.data, a.spmm(&x).data);
    }

    #[test]
    fn parallel_matvec_and_transpose_bitwise_match_serial() {
        forall(
            39,
            10,
            |r| {
                let rows = 4 + r.below(50);
                let cols = 4 + r.below(50);
                let coo = random_coo(r, rows, cols, rows * 4);
                let x: Vec<f64> = (0..cols).map(|_| r.normal()).collect();
                (coo, x)
            },
            |(coo, x)| {
                let a = Csr::from_coo(coo);
                let want_y = a.matvec(x);
                let want_t = a.transpose();
                for threads in [2usize, 4] {
                    let exec = ExecPolicy::with_threads(threads);
                    check(a.matvec_with(x, &exec) == want_y, "matvec differs")?;
                    let t = a.transpose_with(&exec);
                    check(
                        t.indptr == want_t.indptr
                            && t.indices == want_t.indices
                            && t.values == want_t.values,
                        format!("transpose differs at {threads} threads"),
                    )?;
                }
                Ok(())
            },
        );
    }
}
