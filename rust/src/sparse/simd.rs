//! Explicit SIMD inner loops for the width-8 column lane (opt-in via
//! the `simd` cargo feature).
//!
//! The const-generic lanes in `csr`/`sellcs` autovectorize well, but
//! leave scheduling to the compiler; these helpers pin the hot
//! accumulate loop to AVX2 (`x86_64`, runtime-detected with
//! `is_x86_feature_detected!`) or NEON (`aarch64`, a baseline feature)
//! vector ops. On any other architecture — or when the CPU lacks AVX2 —
//! [`lane8_fast`] returns `false` and callers take the autovectorized
//! path.
//!
//! ## Bitwise contract
//!
//! Each helper performs, per output element, the exact float-op
//! sequence of the scalar kernel: `acc[c] += aij * x[j*d + c0 + c]`,
//! one multiply then one add, in ascending `k` order. **FMA is
//! explicitly excluded**: a fused multiply-add skips the intermediate
//! rounding and changes output bits, which would break the
//! backend-interchangeability contract (SELL ≡ CSR ≡ serial reference).
//! We only use `mul` + `add` intrinsics, and Rust/LLVM never contracts
//! separate mul/add into FMA without explicit fast-math, so the fast
//! path is bitwise-equal to the fallback.

/// Whether the explicit width-8 helpers may run on this host.
#[inline]
pub fn lane8_fast() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(target_arch = "aarch64")]
    {
        true // NEON is a baseline feature of aarch64.
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        false
    }
}

/// Accumulate one CSR row over lane columns `[c0, c0 + 8)`:
/// `acc[c] = Σ_k val[k] · x[idx[k]·d + c0 + c]`, ascending `k`.
///
/// # Safety
///
/// [`lane8_fast`] must have returned `true`, every `idx[k]` must satisfy
/// `idx[k] as usize * d + c0 + 8 <= x.len()`, and `idx`/`val` must have
/// equal lengths.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn row_acc8(idx: &[u32], val: &[f64], x: &[f64], d: usize, c0: usize) -> [f64; 8] {
    use std::arch::x86_64::*;
    let mut a0 = _mm256_setzero_pd();
    let mut a1 = _mm256_setzero_pd();
    let xp = x.as_ptr();
    for (&j, &aij) in idx.iter().zip(val) {
        let p = unsafe { xp.add(j as usize * d + c0) };
        let va = _mm256_set1_pd(aij);
        a0 = _mm256_add_pd(a0, _mm256_mul_pd(va, unsafe { _mm256_loadu_pd(p) }));
        a1 = _mm256_add_pd(a1, _mm256_mul_pd(va, unsafe { _mm256_loadu_pd(p.add(4)) }));
    }
    let mut out = [0.0f64; 8];
    unsafe {
        _mm256_storeu_pd(out.as_mut_ptr(), a0);
        _mm256_storeu_pd(out.as_mut_ptr().add(4), a1);
    }
    out
}

/// NEON version of [`row_acc8`]; same contract, four 2-wide registers.
///
/// # Safety
///
/// Same bounds contract as the AVX2 version.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub unsafe fn row_acc8(idx: &[u32], val: &[f64], x: &[f64], d: usize, c0: usize) -> [f64; 8] {
    use std::arch::aarch64::*;
    let mut a = unsafe { [vdupq_n_f64(0.0); 4] };
    let xp = x.as_ptr();
    for (&j, &aij) in idx.iter().zip(val) {
        let p = unsafe { xp.add(j as usize * d + c0) };
        let va = unsafe { vdupq_n_f64(aij) };
        for (q, acc) in a.iter_mut().enumerate() {
            *acc = unsafe { vaddq_f64(*acc, vmulq_f64(va, vld1q_f64(p.add(2 * q)))) };
        }
    }
    let mut out = [0.0f64; 8];
    for (q, acc) in a.iter().enumerate() {
        unsafe { vst1q_f64(out.as_mut_ptr().add(2 * q), *acc) };
    }
    out
}

/// Portable stub so the crate still compiles with `--features simd` on
/// other architectures; never called ([`lane8_fast`] is `false`).
///
/// # Safety
///
/// Same bounds contract as the AVX2 version.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub unsafe fn row_acc8(idx: &[u32], val: &[f64], x: &[f64], d: usize, c0: usize) -> [f64; 8] {
    let mut acc = [0.0f64; 8];
    for (&j, &aij) in idx.iter().zip(val) {
        let base = j as usize * d + c0;
        for (c, a) in acc.iter_mut().enumerate() {
            *a += aij * x[base + c];
        }
    }
    acc
}

/// Accumulate a SELL-C-σ group of four slots over lane columns
/// `[c0, c0 + 8)`. Entry `g` of depth `k` lives at
/// `base + k * stride + g`; the `k` loop is ascending, so each slot sees
/// its entries in original column order — identical to the scalar
/// `group_lane`.
///
/// # Safety
///
/// [`lane8_fast`] must have returned `true`;
/// `base + (len-1)*stride + 4 <= values.len()` (equal `indices` length)
/// and every stored index must satisfy `j·d + c0 + 8 <= x.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn sell_acc8x4(
    values: &[f64],
    indices: &[u32],
    base: usize,
    stride: usize,
    len: usize,
    x: &[f64],
    d: usize,
    c0: usize,
    acc: &mut [[f64; 8]; 4],
) {
    use std::arch::x86_64::*;
    let mut a = [[_mm256_setzero_pd(); 2]; 4];
    let xp = x.as_ptr();
    for k in 0..len {
        let e = base + k * stride;
        for (g, ag) in a.iter_mut().enumerate() {
            let aij = unsafe { *values.get_unchecked(e + g) };
            let j = unsafe { *indices.get_unchecked(e + g) } as usize;
            let p = unsafe { xp.add(j * d + c0) };
            let va = _mm256_set1_pd(aij);
            ag[0] = _mm256_add_pd(ag[0], _mm256_mul_pd(va, unsafe { _mm256_loadu_pd(p) }));
            ag[1] = _mm256_add_pd(ag[1], _mm256_mul_pd(va, unsafe { _mm256_loadu_pd(p.add(4)) }));
        }
    }
    for (g, ag) in a.iter().enumerate() {
        unsafe {
            _mm256_storeu_pd(acc[g].as_mut_ptr(), ag[0]);
            _mm256_storeu_pd(acc[g].as_mut_ptr().add(4), ag[1]);
        }
    }
}

/// NEON version of [`sell_acc8x4`]; same contract.
///
/// # Safety
///
/// Same bounds contract as the AVX2 version.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
pub unsafe fn sell_acc8x4(
    values: &[f64],
    indices: &[u32],
    base: usize,
    stride: usize,
    len: usize,
    x: &[f64],
    d: usize,
    c0: usize,
    acc: &mut [[f64; 8]; 4],
) {
    use std::arch::aarch64::*;
    let mut a = unsafe { [[vdupq_n_f64(0.0); 4]; 4] };
    let xp = x.as_ptr();
    for k in 0..len {
        let e = base + k * stride;
        for (g, ag) in a.iter_mut().enumerate() {
            let aij = unsafe { *values.get_unchecked(e + g) };
            let j = unsafe { *indices.get_unchecked(e + g) } as usize;
            let p = unsafe { xp.add(j * d + c0) };
            let va = unsafe { vdupq_n_f64(aij) };
            for (q, aq) in ag.iter_mut().enumerate() {
                *aq = unsafe { vaddq_f64(*aq, vmulq_f64(va, vld1q_f64(p.add(2 * q)))) };
            }
        }
    }
    for (g, ag) in a.iter().enumerate() {
        for (q, aq) in ag.iter().enumerate() {
            unsafe { vst1q_f64(acc[g].as_mut_ptr().add(2 * q), *aq) };
        }
    }
}

/// Portable stub (never called; see [`row_acc8`]'s stub).
///
/// # Safety
///
/// Same bounds contract as the AVX2 version.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
#[allow(clippy::too_many_arguments)]
pub unsafe fn sell_acc8x4(
    values: &[f64],
    indices: &[u32],
    base: usize,
    stride: usize,
    len: usize,
    x: &[f64],
    d: usize,
    c0: usize,
    acc: &mut [[f64; 8]; 4],
) {
    for k in 0..len {
        let e = base + k * stride;
        for g in 0..4 {
            let aij = values[e + g];
            let xb = indices[e + g] as usize * d + c0;
            for c in 0..8 {
                acc[g][c] += aij * x[xb + c];
            }
        }
    }
}
