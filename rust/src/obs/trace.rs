//! Span collection: bounded per-thread ring buffers drained into a
//! process-wide [`Trace`].
//!
//! Every thread that finishes a traced span appends a [`TraceEvent`] to
//! its own fixed-capacity ring buffer (allocated once, on the thread's
//! first span; full rings overwrite their oldest events and count the
//! drops). The buffer is registered in a global list on creation, so
//! [`drain_trace`] can collect spans from *every* thread that ever
//! recorded — including the detached persistent pool workers, which are
//! parked between regions and never exit. The record path touches only
//! the recording thread's own ring (its mutex is uncontended except
//! against a concurrent drain); nothing global is locked per span.
//!
//! Timestamps are monotonic nanoseconds since the first [`now_ns`] call
//! in the process, so spans from different threads share one time axis —
//! which is exactly what the Chrome `trace_event` export needs.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Spans each thread retains before overwriting its oldest (~640 KiB).
const RING_CAP: usize = 1 << 14;

/// Monotonic nanoseconds since the process-wide trace epoch (the first
/// call). All spans on all threads share this axis.
pub fn now_ns() -> u64 {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    ANCHOR.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// One finished span, as stored in the ring buffers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Small sequential id of the recording thread (1-based).
    pub tid: u64,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Nesting depth at record time (0 = top-level span on its thread).
    pub depth: u16,
}

struct Ring {
    buf: Vec<TraceEvent>,
    /// Oldest element once the buffer is full (next overwrite position).
    head: usize,
    dropped: u64,
}

struct Slot {
    tid: u64,
    ring: Mutex<Ring>,
}

static REGISTRY: Mutex<Vec<Arc<Slot>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LOCAL: RefCell<Option<Arc<Slot>>> = const { RefCell::new(None) };
    static DEPTH: Cell<u16> = const { Cell::new(0) };
}

/// Current span nesting depth on this thread; increments it.
pub(crate) fn depth_push() -> u16 {
    let d = DEPTH.get();
    DEPTH.set(d.saturating_add(1));
    d
}

pub(crate) fn depth_pop() {
    DEPTH.set(DEPTH.get().saturating_sub(1));
}

/// Append a finished span to this thread's ring (registering the ring
/// globally on first use).
pub(crate) fn record(name: &'static str, start_ns: u64, end_ns: u64, depth: u16) {
    LOCAL.with(|local| {
        let mut local = local.borrow_mut();
        let slot = local.get_or_insert_with(|| {
            let slot = Arc::new(Slot {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                ring: Mutex::new(Ring {
                    buf: Vec::with_capacity(RING_CAP),
                    head: 0,
                    dropped: 0,
                }),
            });
            let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
            reg.push(slot.clone());
            slot
        });
        let ev = TraceEvent { name, tid: slot.tid, start_ns, end_ns, depth };
        let mut ring = slot.ring.lock().unwrap_or_else(|e| e.into_inner());
        if ring.buf.len() < RING_CAP {
            ring.buf.push(ev);
        } else {
            let head = ring.head;
            ring.buf[head] = ev;
            ring.head = (head + 1) % RING_CAP;
            ring.dropped += 1;
        }
    });
}

/// Collect (and clear) every thread's ring into one [`Trace`], sorted by
/// start time. Threads keep recording into their emptied rings.
pub fn drain_trace() -> Trace {
    let slots: Vec<Arc<Slot>> = {
        let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
        reg.clone()
    };
    let mut events = Vec::new();
    let mut dropped = 0u64;
    for slot in slots {
        let mut ring = slot.ring.lock().unwrap_or_else(|e| e.into_inner());
        let head = ring.head;
        events.extend_from_slice(&ring.buf[head..]);
        events.extend_from_slice(&ring.buf[..head]);
        dropped += ring.dropped;
        ring.buf.clear();
        ring.head = 0;
        ring.dropped = 0;
    }
    events.sort_by_key(|e| (e.start_ns, e.tid));
    Trace { events, dropped }
}

/// A drained set of spans: the process-wide view the exporters run on.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Spans lost to ring overwrites before this drain.
    pub dropped: u64,
}

impl Trace {
    /// Chrome `trace_event` JSON (the object form): load the file in
    /// `chrome://tracing` or <https://ui.perfetto.dev>. Complete events
    /// (`"ph": "X"`) with microsecond timestamps on one shared clock.
    pub fn to_chrome_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.to_string()));
                m.insert("cat".to_string(), Json::Str("cse".to_string()));
                m.insert("ph".to_string(), Json::Str("X".to_string()));
                m.insert("pid".to_string(), Json::Num(1.0));
                m.insert("tid".to_string(), Json::Num(e.tid as f64));
                m.insert("ts".to_string(), Json::Num(e.start_ns as f64 / 1e3));
                m.insert(
                    "dur".to_string(),
                    Json::Num(e.end_ns.saturating_sub(e.start_ns) as f64 / 1e3),
                );
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("traceEvents".to_string(), Json::Arr(events));
        top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
        top.insert("droppedEvents".to_string(), Json::Num(self.dropped as f64));
        Json::Obj(top)
    }

    /// Text flamegraph-style summary: one line per span name, indented by
    /// its minimum nesting depth, with an inclusive-time bar. Durations
    /// are inclusive of child spans (like a flamegraph frame).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        struct Agg {
            count: u64,
            total_ns: u64,
            min_depth: u16,
        }
        let mut by_name: BTreeMap<&'static str, Agg> = BTreeMap::new();
        for e in &self.events {
            let a = by_name
                .entry(e.name)
                .or_insert_with(|| Agg { count: 0, total_ns: 0, min_depth: e.depth });
            a.count += 1;
            a.total_ns += e.end_ns.saturating_sub(e.start_ns);
            a.min_depth = a.min_depth.min(e.depth);
        }
        let mut rows: Vec<(&'static str, Agg)> = by_name.into_iter().collect();
        rows.sort_by(|a, b| (a.1.min_depth, b.1.total_ns).cmp(&(b.1.min_depth, a.1.total_ns)));
        let max_total = rows.iter().map(|r| r.1.total_ns).max().unwrap_or(1).max(1);
        let mut out = String::new();
        let _ = writeln!(out, "trace: {} spans ({} dropped)", self.events.len(), self.dropped);
        for (name, a) in &rows {
            let indent = "  ".repeat(a.min_depth as usize);
            let label = format!("{indent}{name}");
            let bar_len = ((a.total_ns as f64 / max_total as f64) * 24.0).round() as usize;
            let _ = writeln!(
                out,
                "  {label:<28} {:>8}x  total {:>10}  mean {:>10}  {}",
                a.count,
                crate::util::human_secs(a.total_ns as f64 / 1e9),
                crate::util::human_secs(a.total_ns as f64 / 1e9 / a.count.max(1) as f64),
                "#".repeat(bar_len.max(1)),
            );
        }
        out
    }
}
