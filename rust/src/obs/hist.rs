//! Lock-free log-bucketed histograms (HDR-style).
//!
//! A [`Histogram`] is a fixed array of 64 atomic counters, one per
//! power-of-two bucket: bucket 0 holds the value 0, bucket `i ≥ 1` holds
//! values in `[2^(i-1), 2^i - 1]` (the last bucket is open-ended). That
//! is ≤ 2× relative error per recorded value — plenty for latency and
//! size distributions — while `record` is four relaxed atomic ops with no
//! locks and no allocation, so recorders on the pool's hot paths never
//! contend. Percentiles are **exact on the bucket grid**: the reported
//! quantile is the upper edge of the bucket containing the rank (clamped
//! to the exact observed maximum), not an extrapolation from a mean.
//!
//! Shard-local histograms can be [`Histogram::merge_from`]-combined, and
//! [`HistSnapshot`] supports interval deltas (`sub`) so callers can
//! report percentiles for one measurement window of a long-lived
//! histogram (see `coordinator::service::measure_serving`).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log buckets (`u64` has 64 bit positions).
pub const BUCKETS: usize = 64;

/// Bucket index for a value: 0 for 0, else `floor(log2 v) + 1`, clamped.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

/// Smallest value that lands in bucket `i`.
#[inline]
pub fn bucket_lo(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << (i - 1)
    }
}

/// Largest value that lands in bucket `i`.
#[inline]
pub fn bucket_hi(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Concurrent log-bucketed histogram. All methods are lock-free; `record`
/// is a handful of relaxed atomic increments.
pub struct Histogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub const fn new() -> Histogram {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            counts: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one value (a latency in ns, a candidate count, …).
    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of all recorded values (wrapping only past `u64::MAX`).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Exact mean of recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Percentile `p ∈ [0, 100]` on the bucket grid (see module docs).
    pub fn percentile(&self, p: f64) -> u64 {
        self.snapshot().percentile(p)
    }

    /// Add every counter of `other` into `self` (shard merge). The result
    /// is exactly the histogram of the concatenated value streams.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.counts.iter().zip(&other.counts) {
            let c = theirs.load(Ordering::Relaxed);
            if c > 0 {
                mine.fetch_add(c, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(other.count(), Ordering::Relaxed);
        self.sum.fetch_add(other.sum(), Ordering::Relaxed);
        self.max.fetch_max(other.max(), Ordering::Relaxed);
    }

    /// Point-in-time copy. Under concurrent recording the bucket counts
    /// are each individually exact but may lag one another by in-flight
    /// records; derived statistics use the bucket counts as their own
    /// total, so they are always self-consistent.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (out, c) in counts.iter_mut().zip(&self.counts) {
            *out = c.load(Ordering::Relaxed);
        }
        HistSnapshot { counts, count: self.count(), sum: self.sum(), max: self.max() }
    }
}

/// Plain-integer copy of a [`Histogram`], for delta windows and reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub counts: [u64; BUCKETS],
    pub count: u64,
    pub sum: u64,
    /// Maximum over the histogram's whole lifetime (see [`Self::sub`]).
    pub max: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot { counts: [0; BUCKETS], count: 0, sum: 0, max: 0 }
    }
}

impl HistSnapshot {
    /// Mean of recorded values (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Percentile `p ∈ [0, 100]`: the upper edge of the bucket holding
    /// rank `ceil(p/100 · total)` (clamped to the observed maximum), or 0
    /// when the snapshot is empty. Monotone in `p` by construction.
    pub fn percentile(&self, p: f64) -> u64 {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let p = p.clamp(0.0, 100.0);
        let rank = ((p / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_hi(i).min(self.max);
            }
        }
        self.max
    }

    /// The delta window `self − earlier` (per-bucket saturating), for
    /// percentiles over one measurement interval of a shared histogram.
    /// `max` stays the lifetime maximum — it cannot be windowed.
    pub fn sub(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut counts = [0u64; BUCKETS];
        for (i, out) in counts.iter_mut().enumerate() {
            *out = self.counts[i].saturating_sub(earlier.counts[i]);
        }
        HistSnapshot {
            counts,
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
            max: self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::ExecPolicy;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_boundaries_round_trip() {
        for i in 0..BUCKETS {
            let (lo, hi) = (bucket_lo(i), bucket_hi(i));
            assert!(lo <= hi, "bucket {i}");
            assert_eq!(bucket_index(lo), i, "lo of bucket {i}");
            assert_eq!(bucket_index(hi), i, "hi of bucket {i}");
        }
        for k in 0..63 {
            assert_eq!(bucket_index(1u64 << k), k + 1);
            if k > 0 {
                assert_eq!(bucket_index((1u64 << k) - 1), k);
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        // Adjacent buckets tile the value line with no gap or overlap.
        for i in 1..BUCKETS {
            assert_eq!(bucket_lo(i), bucket_hi(i - 1) + 1);
        }
    }

    #[test]
    fn percentiles_are_monotone_and_bounded() {
        let h = Histogram::new();
        let mut rng = Rng::new(41);
        let mut true_max = 0u64;
        for _ in 0..5000 {
            let v = rng.below(1_000_000) as u64;
            true_max = true_max.max(v);
            h.record(v);
        }
        assert_eq!(h.count(), 5000);
        assert_eq!(h.max(), true_max);
        let mut prev = 0u64;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 99.9, 100.0] {
            let q = h.percentile(p);
            assert!(q >= prev, "p{p} = {q} < p_prev = {prev}");
            assert!(q <= true_max);
            prev = q;
        }
        assert_eq!(h.percentile(100.0), true_max, "p100 is the exact max");
        // Grid accuracy: p50 is within 2x of the exact median's bucket.
        let q50 = h.percentile(50.0);
        assert!(q50 >= bucket_lo(bucket_index(q50)));
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.snapshot(), HistSnapshot::default());
    }

    #[test]
    fn merge_of_shards_equals_whole() {
        let whole = Histogram::new();
        let shards: Vec<Histogram> = (0..4).map(|_| Histogram::new()).collect();
        let mut rng = Rng::new(42);
        for k in 0..2000 {
            let v = rng.below(1 << 20) as u64;
            whole.record(v);
            shards[k % 4].record(v);
        }
        let merged = Histogram::new();
        for s in &shards {
            merged.merge_from(s);
        }
        assert_eq!(merged.snapshot(), whole.snapshot());
        assert_eq!(merged.percentile(99.0), whole.percentile(99.0));
    }

    #[test]
    fn concurrent_recorders_on_pool_lose_nothing() {
        let h = Histogram::new();
        let n = 10_000u64;
        ExecPolicy::with_threads(4).run_indexed(n as usize, |k| h.record(k as u64));
        let s = h.snapshot();
        assert_eq!(s.count, n);
        assert_eq!(s.sum, n * (n - 1) / 2);
        assert_eq!(s.max, n - 1);
        assert_eq!(s.counts.iter().sum::<u64>(), n);
        // Values 0..n are dense, so every bucket count is predictable:
        // bucket i holds min(2^(i-1), n - 2^(i-1)) values for i >= 1.
        for i in 0..BUCKETS {
            let expect = (0..n).filter(|&v| bucket_index(v) == i).count() as u64;
            assert_eq!(s.counts[i], expect, "bucket {i}");
        }
    }

    #[test]
    fn delta_windows_subtract_cleanly() {
        let h = Histogram::new();
        for v in [1u64, 5, 9, 200] {
            h.record(v);
        }
        let before = h.snapshot();
        for v in [3u64, 1000, 1001] {
            h.record(v);
        }
        let delta = h.snapshot().sub(&before);
        assert_eq!(delta.count, 3);
        assert_eq!(delta.sum, 2004);
        assert_eq!(delta.counts.iter().sum::<u64>(), 3);
        // p100 of the window clamps to the lifetime max, which here is
        // also the window max.
        assert_eq!(delta.percentile(100.0), 1001);
    }
}
