//! Zero-dependency observability: histograms, tracing spans, and stage
//! profiling for the compute and serving hot paths.
//!
//! The paper's pitch is a complexity claim — `O((T+n) log n)` embedding
//! instead of an SVD — so the repro must be able to *attribute* wall
//! clock to its stages (matvec cascade vs. orthogonalization vs. index
//! probing) rather than report one end-to-end number. This module is
//! that layer, built on the same constraint as the rest of the crate:
//! no external dependencies, no feature gates, lock-free on hot paths.
//!
//! Three pieces:
//!
//! * **Histograms** ([`hist`]) — 64-bucket log-spaced (HDR-style) atomic
//!   histograms with exact count/sum/max and p50/p90/p99 on the bucket
//!   grid. They back every per-stage timing below and the serving-path
//!   latency/candidate metrics in `coordinator::Metrics`.
//! * **Tracing spans** ([`trace`]) — a guard API ([`span`]) recording
//!   monotonic start/end into bounded per-thread ring buffers, drained
//!   process-wide by [`drain_trace`] into a [`Trace`] that exports
//!   Chrome `trace_event` JSON (open in `chrome://tracing` or Perfetto)
//!   and a text flamegraph-style summary.
//! * **Stage profiling** — a fixed registry of [`Stage`]s ([`STAGES`])
//!   instrumenting the pool (`par::pool` region dispatch, park/wake,
//!   per-worker busy time — [`poolstats`]), the kernel spine
//!   (`Csr::spmm_into_ws`, `apply_series_ws`, CGS2 orthogonalization,
//!   Lanczos reorthogonalization, k-means), the coordinator (per-shard
//!   queue wait vs. run) and the serving path (per-query hash / probe /
//!   scan / re-rank). [`ObsReport::capture`] snapshots all of it.
//!
//! ## Cost model
//!
//! Everything is **off by default**. A [`span`] call with stats disabled
//! is one relaxed atomic load; the always-on pool counters are one or
//! two relaxed increments per parallel region (verified <5% on the
//! `region_overhead` bench). With `--stats` each span adds two monotonic
//! clock reads and four relaxed atomic increments — no locks, no
//! allocation, so steady-state iterations stay allocation-free. With
//! `--trace` each span additionally appends 40 bytes to its thread's
//! preallocated ring buffer (uncontended mutex; oldest spans are
//! overwritten once the ring is full, and the drop count is reported).
//!
//! ## Usage
//!
//! ```
//! use cse::obs;
//! obs::set_stats(true);
//! {
//!     let _g = obs::span(&obs::SPMM); // records on scope exit
//!     // ... kernel work ...
//! }
//! assert!(obs::SPMM.hist.count() >= 1);
//! println!("{}", obs::ObsReport::capture().render());
//! obs::set_stats(false);
//! ```
//!
//! On the CLI every subcommand takes `--stats` (print the report at job
//! end) and `--trace <out.json>` (write the Chrome trace).

pub mod hist;
pub mod trace;

pub use hist::{Histogram, HistSnapshot};
pub use trace::{drain_trace, now_ns, Trace, TraceEvent};

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::json::Json;

static STATS: AtomicBool = AtomicBool::new(false);
static TRACING: AtomicBool = AtomicBool::new(false);

/// Enable/disable stage timing (histogram recording via [`span`]).
pub fn set_stats(on: bool) {
    STATS.store(on, Ordering::Relaxed);
}

/// Enable/disable span collection for trace export. Tracing implies
/// stats; disabling tracing leaves stats in its current state.
pub fn set_tracing(on: bool) {
    if on {
        STATS.store(true, Ordering::Relaxed);
    }
    TRACING.store(on, Ordering::Relaxed);
}

#[inline]
pub fn stats_enabled() -> bool {
    STATS.load(Ordering::Relaxed)
}

#[inline]
pub fn tracing_enabled() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// A named instrumentation point with its duration histogram (ns).
/// Stages are `static`s so recording needs no registry lookup.
pub struct Stage {
    pub name: &'static str,
    pub hist: Histogram,
}

impl Stage {
    pub const fn new(name: &'static str) -> Stage {
        Stage { name, hist: Histogram::new() }
    }
}

macro_rules! declare_stages {
    ($($(#[$doc:meta])* $id:ident => $name:literal),* $(,)?) => {
        $($(#[$doc])* pub static $id: Stage = Stage::new($name);)*
        /// Every declared stage, in reporting order — the set the CI
        /// trace smoke-check asserts against.
        pub static STAGES: &[&Stage] = &[$(&$id),*];
    };
}

declare_stages! {
    /// One sparse block-product (`spmm_into_ws`, CSR or SELL-C-σ).
    SPMM => "spmm",
    /// One runtime kernel-autotune sweep (`sparse::tune`, cache misses
    /// only — cache hits never enter the tuner).
    AUTOTUNE => "autotune",
    /// One NUMA first-touch repack of a sparse operator's arrays
    /// (`Csr::place` / `SellCs::place`).
    NUMA_PLACE => "numa_place",
    /// One polynomial three-term-recursion pass (`apply_series_ws`).
    APPLY_SERIES => "apply_series",
    /// One CGS2/MGS orthonormalization (`mgs_orthonormalize_ws`).
    ORTHO => "orthogonalization",
    /// One Lanczos two-pass reorthogonalization sweep.
    LANCZOS_REORTH => "lanczos_reorth",
    /// One k-means assignment pass over all rows.
    KMEANS_ASSIGN => "kmeans_assign",
    /// One k-means stripe-parallel centroid update.
    KMEANS_UPDATE => "kmeans_update",
    /// One parallel region dispatched through `par::pool`.
    POOL_REGION => "pool_region",
    /// Coordinator worker: waiting on the bounded shard queue.
    SHARD_WAIT => "shard_queue_wait",
    /// Coordinator worker: running one column shard's cascade.
    SHARD_RUN => "shard_run",
    /// Coordinator worker: re-executing a shard after a caught panic or
    /// numerical blow-up (the fault-tolerance retry path).
    SHARD_RETRY => "shard_retry",
    /// One serviced similarity query (corr or top-k), end to end.
    QUERY => "query",
    /// SimHash query: hyperplane projections + signature packing.
    QUERY_HASH => "query_hash",
    /// SimHash query: multi-probe bucket lookups across tables.
    QUERY_PROBE => "query_probe",
    /// SimHash query: candidate id sort + dedup.
    QUERY_SCAN => "query_scan",
    /// Exact-correlation re-ranking of the candidate set.
    QUERY_RERANK => "query_rerank",
}

/// RAII span: times the scope it lives in, recording into the stage's
/// histogram (under `--stats`) and the thread's trace ring (under
/// `--trace`). When stats are disabled this is one atomic load.
pub struct Span {
    stage: &'static Stage,
    start_ns: u64,
    depth: u16,
    recording: bool,
    traced: bool,
}

/// Open a span on `stage`; it records when dropped.
#[must_use = "a span measures the scope it is alive in"]
#[inline]
pub fn span(stage: &'static Stage) -> Span {
    if !stats_enabled() {
        return Span { stage, start_ns: 0, depth: 0, recording: false, traced: false };
    }
    let traced = tracing_enabled();
    let depth = if traced { trace::depth_push() } else { 0 };
    Span { stage, start_ns: trace::now_ns(), depth, recording: true, traced }
}

impl Span {
    /// Discard without recording anything (e.g. a queue wait that ended
    /// in shutdown rather than work).
    pub fn cancel(&mut self) {
        if self.traced {
            trace::depth_pop();
            self.traced = false;
        }
        self.recording = false;
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.traced {
            trace::depth_pop();
        }
        if !self.recording {
            return;
        }
        let end = trace::now_ns();
        self.stage.hist.record(end.saturating_sub(self.start_ns));
        if self.traced {
            trace::record(self.stage.name, self.start_ns, end, self.depth);
        }
    }
}

/// Always-on pool counters (relaxed atomics — the "few atomics per
/// region" budget) plus stats-gated per-worker busy time. Written by
/// `par::pool`, read by [`ObsReport`].
pub mod poolstats {
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Worker busy-time slots (worker ids wrap past this).
    pub const MAX_WORKERS: usize = 64;

    /// Parallel regions dispatched (pooled or inline).
    pub static REGIONS: AtomicU64 = AtomicU64::new(0);
    /// Regions that ran inline on the caller (nested region on a pool
    /// worker, a concurrent submitter holding the pool, or a region too
    /// small to go wide).
    pub static INLINE_REGIONS: AtomicU64 = AtomicU64::new(0);
    /// Pool wake-ups broadcast (one `notify_all` per pooled region).
    pub static WAKES: AtomicU64 = AtomicU64::new(0);
    /// Times a worker parked on the condvar between regions.
    pub static PARKS: AtomicU64 = AtomicU64::new(0);

    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);
    static WORKER_BUSY_NS: [AtomicU64; MAX_WORKERS] = [ZERO; MAX_WORKERS];

    /// Credit `ns` of claimed-task time to pool worker `id`.
    #[inline]
    pub fn add_worker_busy(id: usize, ns: u64) {
        WORKER_BUSY_NS[id % MAX_WORKERS].fetch_add(ns, Ordering::Relaxed);
    }

    /// `(worker id, busy ns)` for every worker that recorded any.
    pub fn worker_busy_ns() -> Vec<(usize, u64)> {
        WORKER_BUSY_NS
            .iter()
            .enumerate()
            .map(|(i, a)| (i, a.load(Ordering::Relaxed)))
            .filter(|&(_, ns)| ns > 0)
            .collect()
    }

    /// Snapshot of every pool counter.
    pub fn capture() -> super::PoolStats {
        super::PoolStats {
            regions: REGIONS.load(Ordering::Relaxed),
            inline_regions: INLINE_REGIONS.load(Ordering::Relaxed),
            wakes: WAKES.load(Ordering::Relaxed),
            parks: PARKS.load(Ordering::Relaxed),
            worker_busy_ns: worker_busy_ns(),
        }
    }
}

/// Always-on failure/robustness counters (relaxed atomics, same budget
/// as [`poolstats`]). Written by the coordinator retry path, the
/// serving fallback/shedding paths, and `crate::fault`; read by
/// [`ObsReport`] so recoveries are visible, not silent.
pub mod failstats {
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Shard re-executions after a caught panic or blow-up.
    pub static SHARD_RETRIES: AtomicU64 = AtomicU64::new(0);
    /// Shards that exhausted their retry budget (the job failed).
    pub static SHARD_FAILURES: AtomicU64 = AtomicU64::new(0);
    /// Jobs/batches aborted at their deadline.
    pub static DEADLINE_ABORTS: AtomicU64 = AtomicU64::new(0);
    /// Top-k queries that fell back from a failed/empty ANN probe to
    /// the exact scanner.
    pub static FALLBACK_EXACT: AtomicU64 = AtomicU64::new(0);
    /// Top-k queries rejected by load shedding.
    pub static QUERIES_SHED: AtomicU64 = AtomicU64::new(0);
    /// Faults injected by an armed `crate::fault` spec (all kinds).
    pub static FAULTS_INJECTED: AtomicU64 = AtomicU64::new(0);

    /// Snapshot of every failure counter.
    pub fn capture() -> super::FailStats {
        super::FailStats {
            shard_retries: SHARD_RETRIES.load(Ordering::Relaxed),
            shard_failures: SHARD_FAILURES.load(Ordering::Relaxed),
            deadline_aborts: DEADLINE_ABORTS.load(Ordering::Relaxed),
            fallback_exact: FALLBACK_EXACT.load(Ordering::Relaxed),
            queries_shed: QUERIES_SHED.load(Ordering::Relaxed),
            faults_injected: FAULTS_INJECTED.load(Ordering::Relaxed),
        }
    }
}

/// Histogram-derived summary of one stage, all durations in µs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageStats {
    pub name: &'static str,
    pub count: u64,
    pub total_ns: u64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
}

/// Pool counter snapshot (see [`poolstats`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PoolStats {
    pub regions: u64,
    pub inline_regions: u64,
    pub wakes: u64,
    pub parks: u64,
    pub worker_busy_ns: Vec<(usize, u64)>,
}

/// Failure counter snapshot (see [`failstats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FailStats {
    pub shard_retries: u64,
    pub shard_failures: u64,
    pub deadline_aborts: u64,
    pub fallback_exact: u64,
    pub queries_shed: u64,
    pub faults_injected: u64,
}

/// Host-topology snapshot (from [`crate::par::topo::detect`]). `pinned`
/// reflects the `--pin` runtime switch, not whether the build can
/// actually pin — a pinned report from a non-`affinity` build means the
/// flag was requested and silently downgraded to a no-op.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TopoStats {
    pub nodes: usize,
    pub physical_cores: usize,
    pub logical_cpus: usize,
    pub smt: bool,
    pub pinned: bool,
}

/// `Snapshot`-style point-in-time report over every declared stage and
/// the pool counters — printed at job end under `--stats`, exported into
/// the bench JSON breakdowns.
#[derive(Clone, Debug, Default)]
pub struct ObsReport {
    /// Stages that recorded at least one span, in [`STAGES`] order.
    pub stages: Vec<StageStats>,
    pub pool: PoolStats,
    pub failures: FailStats,
    pub topology: TopoStats,
}

impl ObsReport {
    pub fn capture() -> ObsReport {
        let stages = STAGES
            .iter()
            .filter_map(|st| {
                let s = st.hist.snapshot();
                if s.count == 0 {
                    return None;
                }
                Some(StageStats {
                    name: st.name,
                    count: s.count,
                    total_ns: s.sum,
                    mean_us: s.mean() / 1e3,
                    p50_us: s.percentile(50.0) as f64 / 1e3,
                    p90_us: s.percentile(90.0) as f64 / 1e3,
                    p99_us: s.percentile(99.0) as f64 / 1e3,
                    max_us: s.max as f64 / 1e3,
                })
            })
            .collect();
        let t = crate::par::topo::detect();
        let topology = TopoStats {
            nodes: t.num_nodes(),
            physical_cores: t.physical_cores(),
            logical_cpus: t.logical_cpus(),
            smt: t.smt(),
            pinned: crate::par::affinity::pinning_enabled(),
        };
        ObsReport { stages, pool: poolstats::capture(), failures: failstats::capture(), topology }
    }

    /// Human-readable table (percentiles are exact on the log-bucket
    /// grid, clamped to the observed max).
    pub fn render(&self) -> String {
        let hs = |us: f64| crate::util::human_secs(us / 1e6);
        let mut out = String::new();
        let _ = writeln!(out, "obs report — per-stage timings (log-bucket histograms):");
        if self.stages.is_empty() {
            let _ = writeln!(out, "  (no stages recorded — enable with --stats or --trace)");
        } else {
            let _ = writeln!(
                out,
                "  {:<18} {:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
                "stage", "count", "total", "mean", "p50", "p90", "p99", "max"
            );
            for s in &self.stages {
                let _ = writeln!(
                    out,
                    "  {:<18} {:>9} {:>11} {:>11} {:>11} {:>11} {:>11} {:>11}",
                    s.name,
                    s.count,
                    crate::util::human_secs(s.total_ns as f64 / 1e9),
                    hs(s.mean_us),
                    hs(s.p50_us),
                    hs(s.p90_us),
                    hs(s.p99_us),
                    hs(s.max_us),
                );
            }
        }
        let p = &self.pool;
        let _ = writeln!(
            out,
            "  pool: {} regions ({} inline), {} wakes, {} parks",
            p.regions, p.inline_regions, p.wakes, p.parks
        );
        if !p.worker_busy_ns.is_empty() {
            let busy: Vec<String> = p
                .worker_busy_ns
                .iter()
                .map(|(id, ns)| format!("w{id} {}", crate::util::human_secs(*ns as f64 / 1e9)))
                .collect();
            let _ = writeln!(out, "  worker busy: {}", busy.join(", "));
        }
        // Always printed (even all-zero) in a grep-friendly k=v form:
        // the chaos-smoke CI job parses `shard_retries=N` out of this.
        let fs = &self.failures;
        let _ = writeln!(
            out,
            "  failures: shard_retries={} shard_failures={} deadline_aborts={} \
             fallback_exact={} queries_shed={} faults_injected={}",
            fs.shard_retries,
            fs.shard_failures,
            fs.deadline_aborts,
            fs.fallback_exact,
            fs.queries_shed,
            fs.faults_injected
        );
        // Same grep-friendly k=v form; the obs-smoke CI job asserts on
        // the `topology: nodes=` prefix.
        let t = &self.topology;
        let _ = writeln!(
            out,
            "  topology: nodes={} physical_cores={} logical_cpus={} smt={} pinned={}",
            t.nodes, t.physical_cores, t.logical_cpus, t.smt, t.pinned
        );
        out
    }

    /// JSON form for the bench artifacts (BENCH_kernels.json /
    /// BENCH_serving.json per-stage breakdowns).
    pub fn to_json(&self) -> Json {
        let mut stages = BTreeMap::new();
        for s in &self.stages {
            let mut m = BTreeMap::new();
            m.insert("count".to_string(), Json::Num(s.count as f64));
            m.insert("total_ms".to_string(), Json::Num(s.total_ns as f64 / 1e6));
            m.insert("mean_us".to_string(), Json::Num(s.mean_us));
            m.insert("p50_us".to_string(), Json::Num(s.p50_us));
            m.insert("p90_us".to_string(), Json::Num(s.p90_us));
            m.insert("p99_us".to_string(), Json::Num(s.p99_us));
            m.insert("max_us".to_string(), Json::Num(s.max_us));
            stages.insert(s.name.to_string(), Json::Obj(m));
        }
        let mut pool = BTreeMap::new();
        pool.insert("regions".to_string(), Json::Num(self.pool.regions as f64));
        pool.insert("inline_regions".to_string(), Json::Num(self.pool.inline_regions as f64));
        pool.insert("wakes".to_string(), Json::Num(self.pool.wakes as f64));
        pool.insert("parks".to_string(), Json::Num(self.pool.parks as f64));
        pool.insert(
            "worker_busy_ms".to_string(),
            Json::Arr(
                self.pool
                    .worker_busy_ns
                    .iter()
                    .map(|(_, ns)| Json::Num(*ns as f64 / 1e6))
                    .collect(),
            ),
        );
        let fs = &self.failures;
        let mut failures = BTreeMap::new();
        failures.insert("shard_retries".to_string(), Json::Num(fs.shard_retries as f64));
        failures.insert("shard_failures".to_string(), Json::Num(fs.shard_failures as f64));
        failures.insert("deadline_aborts".to_string(), Json::Num(fs.deadline_aborts as f64));
        failures.insert("fallback_exact".to_string(), Json::Num(fs.fallback_exact as f64));
        failures.insert("queries_shed".to_string(), Json::Num(fs.queries_shed as f64));
        failures.insert("faults_injected".to_string(), Json::Num(fs.faults_injected as f64));
        let t = &self.topology;
        let mut topology = BTreeMap::new();
        topology.insert("nodes".to_string(), Json::Num(t.nodes as f64));
        topology.insert("physical_cores".to_string(), Json::Num(t.physical_cores as f64));
        topology.insert("logical_cpus".to_string(), Json::Num(t.logical_cpus as f64));
        topology.insert("smt".to_string(), Json::Bool(t.smt));
        topology.insert("pinned".to_string(), Json::Bool(t.pinned));
        let mut top = BTreeMap::new();
        top.insert("stages".to_string(), Json::Obj(stages));
        top.insert("pool".to_string(), Json::Obj(pool));
        top.insert("failures".to_string(), Json::Obj(failures));
        top.insert("topology".to_string(), Json::Obj(topology));
        Json::Obj(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Private test stages: nothing else in the crate records to these,
    // so counts stay exact even with other tests running concurrently.
    static STAGE_A: Stage = Stage::new("obs_test_a");
    static STAGE_B: Stage = Stage::new("obs_test_b");

    #[test]
    fn spans_feed_hists_trace_and_report() {
        // Disabled path first (this test is the only writer of the
        // global flags, so the off state is deterministic here).
        static STAGE_OFF: Stage = Stage::new("obs_test_off");
        assert!(!stats_enabled());
        for _ in 0..10 {
            let _g = span(&STAGE_OFF);
        }
        assert_eq!(STAGE_OFF.hist.count(), 0, "disabled spans record nothing");

        set_tracing(true);
        {
            let _a = span(&STAGE_A);
            let _b = span(&STAGE_B); // nested; drops before _a
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let mut c = span(&STAGE_A);
        c.cancel();
        drop(c);
        set_tracing(false);
        set_stats(false);

        assert_eq!(STAGE_A.hist.count(), 1, "cancelled span must not record");
        assert_eq!(STAGE_B.hist.count(), 1);
        assert!(STAGE_A.hist.max() >= 2_000_000, "span measured the sleep");

        let t = drain_trace();
        let a: Vec<&TraceEvent> =
            t.events.iter().filter(|e| e.name == "obs_test_a").collect();
        let b: Vec<&TraceEvent> =
            t.events.iter().filter(|e| e.name == "obs_test_b").collect();
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        assert_eq!(a[0].depth, 0);
        assert_eq!(b[0].depth, 1, "nested span records its depth");
        assert!(b[0].start_ns >= a[0].start_ns && b[0].end_ns <= a[0].end_ns);
        assert_eq!(a[0].tid, b[0].tid);

        let parsed = Json::parse(&t.to_chrome_json().to_string()).expect("valid chrome JSON");
        assert!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len() >= 2);
        assert!(t.summary().contains("obs_test_a"));
    }

    #[test]
    fn report_captures_recorded_stages_and_valid_json() {
        // Drive a declared stage's histogram directly (no global flags
        // involved), then check the report surfaces it.
        SPMM.hist.record(1_500);
        SPMM.hist.record(2_500_000);
        let rep = ObsReport::capture();
        let s = rep
            .stages
            .iter()
            .find(|s| s.name == "spmm")
            .expect("spmm stage present after recording");
        assert!(s.count >= 2);
        assert!(s.p50_us <= s.p99_us && s.p99_us <= s.max_us + 1e-9);
        assert!(rep.render().contains("spmm"));
        assert!(rep.render().contains("topology: nodes="), "topology line present");
        assert!(rep.topology.logical_cpus >= rep.topology.physical_cores);
        assert!(rep.topology.physical_cores >= 1 && rep.topology.nodes >= 1);
        let j = Json::parse(&rep.to_json().to_string()).expect("report JSON parses");
        assert!(j.get("stages").unwrap().get("spmm").is_some());
        assert!(j.get("pool").is_some());
        assert!(j.get("topology").unwrap().get("nodes").is_some());
    }

    #[test]
    fn stage_registry_names_are_unique() {
        let mut names: Vec<&str> = STAGES.iter().map(|s| s.name).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate stage names");
        assert_eq!(n, 17);
    }
}
