//! Reusable scratch buffers for the iteration hot loops.
//!
//! Every Chebyshev/Lanczos/k-means iteration used to reallocate its
//! working set (`vec![0.0; n*d]`, `.clone()`, fresh partition vectors).
//! A [`Workspace`] is a small arena those loops draw from instead:
//! `take` hands out a zeroed buffer (recycling the largest retired one),
//! `give` retires a buffer for reuse, and [`Workspace::ranges`] is the
//! shared scratch for per-call partition lists. After warm-up a loop
//! that takes and gives symmetrically performs **zero heap allocations
//! per iteration** — measured by the `kernels` bench's allocation
//! counter.
//!
//! The workspace is deliberately dumb: plain `Vec<f64>` recycling, no
//! size classes, no interior mutability — one workspace per thread of
//! control (each coordinator shard worker owns one). Buffers keep their
//! capacity across `give`/`take`, so ping-pong patterns stabilize after
//! the first iteration. Aliasing safety is by construction: `take`
//! transfers ownership out of the arena, so two live buffers can never
//! share storage (property-tested below).

use std::ops::Range;

use super::CancelToken;
use crate::linalg::Mat;

/// A recycling arena of `f64` buffers plus partition scratch.
#[derive(Default)]
pub struct Workspace {
    bufs: Vec<Vec<f64>>,
    /// Reusable `Range` list for kernels that partition per call
    /// (`Csr::spmm_into_ws` and friends) — cleared and refilled by
    /// [`super::even_ranges_into`] / [`super::weighted_ranges_into`].
    pub ranges: Vec<Range<usize>>,
    /// Reusable partition scratch for the SELL-C-σ kernels, which
    /// partition *slices* rather than rows. Separate from
    /// [`Self::ranges`] so a format-mixed pipeline (e.g. CSR transpose
    /// feeding SELL products) never thrashes one list between layouts.
    pub slice_ranges: Vec<Range<usize>>,
    /// Sticky-partition key for [`Self::ranges`]: identifies the
    /// (matrix, policy) pair the cached list was computed for, so
    /// repeated kernel calls over the same operator skip the
    /// `weighted_ranges_into` prefix scan entirely. See
    /// [`super::weighted_ranges_sticky`]. Reuse is bitwise-invisible:
    /// the cached list is exactly what a recompute would produce.
    pub ranges_key: super::StickyKey,
    /// Sticky-partition key for [`Self::slice_ranges`] (the SELL-C-σ
    /// slice partition), independent of the CSR row partition.
    pub slice_ranges_key: super::StickyKey,
    /// Optional cancellation token polled by the kernels that draw
    /// scratch from this workspace (`spmm_into_ws` at row-block or
    /// slice-block granularity, `apply_series_ws` per recurrence step).
    /// `None` — the default — costs one `Option` discriminant branch
    /// per poll.
    pub cancel: Option<CancelToken>,
}

impl Workspace {
    pub const fn new() -> Self {
        Workspace {
            bufs: Vec::new(),
            ranges: Vec::new(),
            slice_ranges: Vec::new(),
            ranges_key: None,
            slice_ranges_key: None,
            cancel: None,
        }
    }

    /// Whether the attached token (if any) has been tripped.
    #[inline]
    pub fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.is_cancelled())
    }

    /// A zeroed buffer of exactly `len` elements, reusing the retired
    /// buffer with the largest capacity when one exists.
    pub fn take(&mut self, len: usize) -> Vec<f64> {
        // Retired buffers are kept sorted by capacity (see `give`), so
        // the best candidate is always last.
        match self.bufs.pop() {
            Some(mut b) => {
                b.clear();
                b.resize(len, 0.0);
                b
            }
            None => vec![0.0; len],
        }
    }

    /// Retire a buffer for later reuse (keeps it sorted by capacity so
    /// `take` grabs the largest first and small stragglers don't pin
    /// big allocations).
    pub fn give(&mut self, buf: Vec<f64>) {
        if buf.capacity() == 0 {
            return;
        }
        let pos = self.bufs.partition_point(|b| b.capacity() <= buf.capacity());
        self.bufs.insert(pos, buf);
    }

    /// [`Self::take`] shaped as a zeroed `rows × cols` matrix.
    pub fn take_mat(&mut self, rows: usize, cols: usize) -> Mat {
        Mat::from_vec(rows, cols, self.take(rows * cols))
    }

    /// Retire a matrix's storage.
    pub fn give_mat(&mut self, m: Mat) {
        self.give(m.data);
    }

    /// Retired buffers currently held (tests/telemetry).
    pub fn retired(&self) -> usize {
        self.bufs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_reuses_retired_storage() {
        let mut ws = Workspace::new();
        let a = ws.take(1000);
        let ptr = a.as_ptr();
        ws.give(a);
        let b = ws.take(500); // smaller fits in the same storage
        assert_eq!(b.as_ptr(), ptr, "capacity must be recycled");
        assert_eq!(b.len(), 500);
        assert!(b.iter().all(|&x| x == 0.0), "take hands out zeroed buffers");
    }

    #[test]
    fn live_buffers_never_alias() {
        let mut ws = Workspace::new();
        let mut a = ws.take(64);
        let mut b = ws.take(64);
        a.fill(1.0);
        b.fill(2.0);
        assert!(a.iter().all(|&x| x == 1.0));
        assert!(b.iter().all(|&x| x == 2.0));
        ws.give(a);
        ws.give(b);
        assert_eq!(ws.retired(), 2);
        let c = ws.take(64);
        let d = ws.take(64);
        assert_ne!(c.as_ptr(), d.as_ptr(), "distinct storage for live takes");
        assert!(c.iter().chain(&d).all(|&x| x == 0.0), "recycled buffers are re-zeroed");
    }

    #[test]
    fn largest_capacity_is_preferred() {
        let mut ws = Workspace::new();
        let small = ws.take(10);
        let big = ws.take(10_000);
        let big_ptr = big.as_ptr();
        ws.give(small);
        ws.give(big);
        let got = ws.take(8_000);
        assert_eq!(got.as_ptr(), big_ptr, "must pick the buffer that avoids reallocating");
    }

    #[test]
    fn mat_roundtrip_keeps_shape_and_zeroes() {
        let mut ws = Workspace::new();
        let mut m = ws.take_mat(7, 3);
        assert_eq!((m.rows, m.cols), (7, 3));
        m.data.fill(9.0);
        ws.give_mat(m);
        let m2 = ws.take_mat(3, 7);
        assert_eq!((m2.rows, m2.cols), (3, 7));
        assert!(m2.data.iter().all(|&x| x == 0.0));
    }
}
