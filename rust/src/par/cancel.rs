//! Cooperative cancellation for long-running compute jobs.
//!
//! A [`CancelToken`] is a shared flag (plus an optional wall-clock
//! deadline) that hot loops poll at natural checkpoints — row blocks in
//! the SpMM kernels, recurrence steps in `apply_series_ws`, shard and
//! stage boundaries in the coordinator. Polling is one relaxed atomic
//! load once the flag is set (or when no deadline is attached), so the
//! checks are free on the fast path; a deadline adds one monotonic clock
//! read per poll until it expires, after which the cached flag answers.
//!
//! Cancellation is *cooperative and lossy by design*: a cancelled kernel
//! may leave its output half-written. Callers that observe cancellation
//! must discard the partial result (the coordinator drops the shard and
//! reports [`crate::coordinator::JobError::DeadlineExceeded`]); nothing
//! downstream ever reads a cancelled block, so the bitwise-determinism
//! contract is unaffected.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A cloneable cancellation handle; all clones share one flag.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
}

impl CancelToken {
    /// A token with no deadline; trips only via [`Self::cancel`].
    pub fn new() -> Self {
        CancelToken::default()
    }

    /// A token that additionally trips once `timeout` has elapsed
    /// (measured from now, checked lazily by [`Self::is_cancelled`]).
    pub fn with_deadline(timeout: Duration) -> Self {
        // `checked_add` so absurd timeouts degrade to "no deadline"
        // instead of panicking on Instant overflow.
        let deadline = Instant::now().checked_add(timeout);
        CancelToken { inner: Arc::new(Inner { cancelled: AtomicBool::new(false), deadline }) }
    }

    /// Trip the token; every clone observes it.
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    /// Whether the token has been cancelled or its deadline has passed.
    /// A passed deadline latches the flag, so subsequent polls are one
    /// relaxed load with no clock read.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        if self.inner.cancelled.load(Ordering::Relaxed) {
            return true;
        }
        match self.inner.deadline {
            Some(dl) if Instant::now() >= dl => {
                self.inner.cancelled.store(true, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// The attached deadline, if any.
    pub fn deadline(&self) -> Option<Instant> {
        self.inner.deadline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clones_share_the_flag() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!t.is_cancelled() && !c.is_cancelled());
        c.cancel();
        assert!(t.is_cancelled() && c.is_cancelled());
    }

    #[test]
    fn deadline_trips_and_latches() {
        let t = CancelToken::with_deadline(Duration::from_millis(5));
        assert!(t.deadline().is_some());
        std::thread::sleep(Duration::from_millis(10));
        assert!(t.is_cancelled(), "deadline must trip after it passes");
        // Latched: the flag now answers without the clock.
        assert!(t.is_cancelled());
    }

    #[test]
    fn generous_deadline_does_not_trip() {
        let t = CancelToken::with_deadline(Duration::from_secs(3600));
        assert!(!t.is_cancelled());
        t.cancel();
        assert!(t.is_cancelled(), "manual cancel works alongside a deadline");
    }

    #[test]
    fn absurd_timeout_degrades_to_no_deadline() {
        let t = CancelToken::with_deadline(Duration::from_secs(u64::MAX));
        assert!(!t.is_cancelled());
    }
}
