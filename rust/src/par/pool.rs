//! The persistent worker pool behind [`crate::par::ExecPolicy`].
//!
//! PR 2's parallel regions paid a `std::thread::scope` spawn+join per
//! region — fine at block-product granularity, ruinous for micro-ops
//! (a spawn is ~10µs; an MGS column dot on a 4k vector is ~1µs). This
//! module keeps one process-wide set of workers **parked on a condvar**
//! between regions; a region submission is one mutex/condvar wake, and
//! region teardown is one latch wait. Workers are detached and live for
//! the process.
//!
//! ## Protocol
//!
//! A region is published as a [`Job`] that lives **on the submitter's
//! stack**: a type-erased `&dyn Fn(usize)` task body, an atomic task
//! cursor, and a completion latch. The submitter
//!
//! 1. takes the pool's `submit` lock (one region at a time — see below),
//! 2. bumps the epoch and stores the job pointer + a participant budget
//!    under the `state` lock, waking all parked workers,
//! 3. runs the claim loop itself, then
//! 4. blocks on the latch until every participant has signalled.
//!
//! Workers wake, and **under the state lock** decide whether to join:
//! if the epoch is new and participant slots remain, they take a slot
//! and only then dereference the job pointer. Losers never touch the
//! job, so the submitter needs to wait only for the winners — after the
//! latch trips, nothing can alias the stack-allocated job and the
//! submitter may return (the borrow the `'static` transmute erased is
//! live for exactly the region's duration).
//!
//! ## Nesting and contention
//!
//! Two situations fall back to running the region **inline on the
//! caller** (bitwise-identical results — the chunk structure, which is
//! what determines every output bit, is fixed by the caller, not by who
//! executes the chunks):
//!
//! * a pool worker submitting a region from inside a task (nested
//!   parallelism) — running it on the pool could deadlock against the
//!   region that worker is already part of;
//! * the `submit` lock is already held (e.g. two coordinator shard
//!   workers both hit a kernel): the second region inlines rather than
//!   serializing behind the first, so shard-level parallelism is never
//!   throttled by kernel-level parallelism.
//!
//! ## Panics
//!
//! A panicking task body stops further claims (the cursor is slammed to
//! the end), the latch still trips, and the payload is re-thrown on the
//! submitting thread — the same observable behaviour as the scoped
//! implementation this replaces.

//! ## Instrumentation
//!
//! The pool feeds [`crate::obs`]: every region bumps the always-on
//! relaxed counters in `obs::poolstats` (dispatched/inline regions,
//! wakes, parks — a few atomics per region, verified <5% overhead by the
//! `region_overhead` bench), and under `--stats` each region is a
//! `pool_region` span and each worker accounts its claimed-task busy
//! time per worker id.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

use crate::obs;

/// A parallel region, stack-allocated in [`run_on_pool`].
struct Job {
    /// Task body with its borrow lifetime erased; valid until the latch
    /// has been signalled by every participant.
    f: &'static (dyn Fn(usize) + Sync),
    tasks: usize,
    /// Next unclaimed task index (shared claim cursor).
    cursor: AtomicUsize,
    /// Count of participants that finished their claim loop.
    done: Mutex<usize>,
    done_cv: Condvar,
    /// First panic payload observed by a participant, if any.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// Raw pointer to a [`Job`], published to workers through [`State`].
/// Safety: workers dereference it only after taking a participant slot
/// under the state lock, and the submitter outlives all participants.
#[derive(Clone, Copy)]
struct JobRef(*const Job);
unsafe impl Send for JobRef {}

struct State {
    /// Bumped once per region; workers track the last epoch they saw.
    epoch: u64,
    job: Option<JobRef>,
    /// Participant slots remaining for the current epoch.
    slots_left: usize,
    /// Workers spawned so far (the pool grows on demand, never shrinks).
    spawned: usize,
}

struct Pool {
    state: Mutex<State>,
    wake: Condvar,
    /// Held for a region's whole lifetime: one pool region at a time.
    submit: Mutex<()>,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// Set for pool workers: nested regions run inline (see module doc).
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(State { epoch: 0, job: None, slots_left: 0, spawned: 0 }),
        wake: Condvar::new(),
        submit: Mutex::new(()),
    })
}

fn spawn_worker(p: &'static Pool, id: usize) {
    std::thread::Builder::new()
        .name("cse-par-worker".into())
        .spawn(move || worker_loop(p, id))
        .expect("failed to spawn pool worker");
}

fn worker_loop(p: &'static Pool, id: usize) {
    IN_POOL.with(|f| f.set(true));
    // Optional node-local core pinning (off-by-default `affinity`
    // feature + runtime `--pin`): moves this thread, never a chunk
    // boundary, so it cannot affect any output bit.
    super::affinity::pin_worker(id);
    let mut seen = 0u64;
    loop {
        // Decide participation under the state lock; dereference the job
        // only after winning a slot.
        let claim: Option<JobRef> = {
            let mut st = p.state.lock().unwrap();
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if st.slots_left > 0 {
                        st.slots_left -= 1;
                        break st.job;
                    }
                    break None;
                }
                obs::poolstats::PARKS.fetch_add(1, Ordering::Relaxed);
                st = p.wake.wait(st).unwrap();
            }
        };
        let Some(JobRef(ptr)) = claim else { continue };
        let job = unsafe { &*ptr };
        let busy_from = if obs::stats_enabled() { Some(obs::now_ns()) } else { None };
        let result = catch_unwind(AssertUnwindSafe(|| {
            // Chaos-test failpoint: one draw per claim, a no-op (single
            // relaxed load) unless a fault spec is armed. An injected
            // panic takes the exact path a real task panic does.
            crate::fault::inject("pool_task");
            loop {
                let k = job.cursor.fetch_add(1, Ordering::Relaxed);
                if k >= job.tasks {
                    break;
                }
                (job.f)(k);
            }
        }));
        if let Some(t0) = busy_from {
            obs::poolstats::add_worker_busy(id, obs::now_ns().saturating_sub(t0));
        }
        if let Err(payload) = result {
            // Stop further claims and record the first payload.
            job.cursor.store(job.tasks, Ordering::Relaxed);
            let mut slot = job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        // Signal the latch. After the guard drops the job must not be
        // touched again: the submitter may free it immediately.
        let mut done = job.done.lock().unwrap();
        *done += 1;
        job.done_cv.notify_all();
        drop(done);
    }
}

/// Whether the current thread is a pool worker (used by tests and by
/// [`run_on_pool`]'s nested-region fallback).
pub fn on_pool_worker() -> bool {
    IN_POOL.with(|f| f.get())
}

/// Run `f(0..tasks)` using up to `threads - 1` pool workers plus the
/// calling thread. Falls back to a plain inline loop when the region
/// cannot (nested) or need not (busy pool, trivial size) go wide —
/// results are identical either way.
pub fn run_on_pool(threads: usize, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    obs::poolstats::REGIONS.fetch_add(1, Ordering::Relaxed);
    let _region_span = obs::span(&obs::POOL_REGION);
    let inline = || {
        obs::poolstats::INLINE_REGIONS.fetch_add(1, Ordering::Relaxed);
        for k in 0..tasks {
            f(k);
        }
    };
    let helpers = threads.saturating_sub(1).min(tasks.saturating_sub(1));
    if helpers == 0 || on_pool_worker() {
        return inline();
    }
    let p = pool();
    // One pool region at a time; a concurrent submitter (another shard
    // worker mid-kernel) inlines instead of queueing. A poisoned lock
    // (an earlier region re-threw a task panic while holding it) is
    // harmless — the pool state it guards is valid between regions.
    let _region = match p.submit.try_lock() {
        Ok(guard) => guard,
        Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        Err(std::sync::TryLockError::WouldBlock) => return inline(),
    };
    // SAFETY: the job (and through it this borrow of `f`) is only ever
    // dereferenced by participants, all of which signal the latch we
    // wait on below before this frame can return.
    let f_static: &'static (dyn Fn(usize) + Sync) =
        unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
    let job = Job {
        f: f_static,
        tasks,
        cursor: AtomicUsize::new(0),
        done: Mutex::new(0),
        done_cv: Condvar::new(),
        panic: Mutex::new(None),
    };
    {
        let mut st = p.state.lock().unwrap();
        while st.spawned < helpers {
            spawn_worker(p, st.spawned);
            st.spawned += 1;
        }
        st.epoch += 1;
        st.job = Some(JobRef(&job));
        st.slots_left = helpers;
        obs::poolstats::WAKES.fetch_add(1, Ordering::Relaxed);
        p.wake.notify_all();
    }
    // The submitter is participant zero.
    let own = catch_unwind(AssertUnwindSafe(|| loop {
        let k = job.cursor.fetch_add(1, Ordering::Relaxed);
        if k >= tasks {
            break;
        }
        f(k);
    }));
    if own.is_err() {
        job.cursor.store(tasks, Ordering::Relaxed);
    }
    // Latch: every slot that was published gets claimed by some worker
    // (all workers eventually observe the epoch), and every claim ends
    // in exactly one latch increment, panic or not.
    {
        let mut done = job.done.lock().unwrap();
        while *done < helpers {
            done = job.done_cv.wait(done).unwrap();
        }
    }
    // Hygiene: drop the dangling pointer before the job leaves scope.
    {
        let mut st = p.state.lock().unwrap();
        st.job = None;
        st.slots_left = 0;
    }
    if let Err(payload) = own {
        resume_unwind(payload);
    }
    if let Some(payload) = job.panic.lock().unwrap().take() {
        resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_workers_across_many_small_regions() {
        // Thousands of tiny regions: with spawn-per-region this test is
        // slow; with the persistent pool it's instant — and more to the
        // point, it must neither deadlock nor leak participants.
        for threads in [2usize, 4] {
            let hits: Vec<AtomicUsize> = (0..16).map(|_| AtomicUsize::new(0)).collect();
            for _ in 0..2000 {
                run_on_pool(threads, hits.len(), &|k| {
                    hits[k].fetch_add(1, Ordering::Relaxed);
                });
            }
            for h in &hits {
                assert_eq!(h.load(Ordering::Relaxed), 2000, "threads={threads}");
            }
            for h in &hits {
                h.store(0, Ordering::Relaxed);
            }
        }
    }

    #[test]
    fn concurrent_submitters_fall_back_without_deadlock() {
        // Simulates coordinator shard workers all hitting kernels: the
        // pool serves one, the rest inline. Every task must run once.
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..300 {
                        let hits: Vec<AtomicUsize> =
                            (0..24).map(|_| AtomicUsize::new(0)).collect();
                        run_on_pool(4, hits.len(), &|k| {
                            hits[k].fetch_add(1, Ordering::Relaxed);
                        });
                        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
                    }
                });
            }
        });
    }

    #[test]
    fn nested_regions_run_inline() {
        let outer: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        run_on_pool(4, outer.len(), &|k| {
            // A region submitted from inside a task must complete (on the
            // pool for the submitter thread, inline on workers).
            let inner: Vec<AtomicUsize> = (0..6).map(|_| AtomicUsize::new(0)).collect();
            run_on_pool(4, inner.len(), &|j| {
                inner[j].fetch_add(1, Ordering::Relaxed);
            });
            assert!(inner.iter().all(|h| h.load(Ordering::Relaxed) == 1));
            outer[k].fetch_add(1, Ordering::Relaxed);
        });
        assert!(outer.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn task_panic_propagates_to_submitter() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            run_on_pool(4, 64, &|k| {
                if k == 33 {
                    panic!("boom in task");
                }
            });
        }));
        assert!(caught.is_err(), "panic must cross the pool");
        // The pool must still be usable afterwards.
        let hits: Vec<AtomicUsize> = (0..10).map(|_| AtomicUsize::new(0)).collect();
        run_on_pool(4, hits.len(), &|k| {
            hits[k].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }
}
