//! CPU / NUMA topology detection from `/sys/devices/system/{cpu,node}`.
//!
//! Zero-dependency: the kernel's sysfs cpulist files ("0-3,8-11") are
//! parsed directly. On non-Linux hosts, in containers that mask sysfs,
//! or on any parse failure, detection degrades gracefully to a single
//! node holding `available_parallelism()` CPUs with no SMT information
//! — every consumer (auto_split, first-touch placement, worker pinning)
//! treats that fallback as "locality unknown, behave as before".
//!
//! Topology is pure scheduling/placement policy: nothing here can move
//! a bit of any result (see `rust/tests/par_determinism.rs`).

use std::path::Path;
use std::sync::OnceLock;

/// Host CPU topology: online CPUs, their NUMA-node grouping, and SMT
/// sibling sets. Constructed by [`detect`] (cached for the process) or
/// from a fixture tree via [`Topology::from_sysfs`] in tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Online CPU ids per NUMA node (index = node id after compaction;
    /// always at least one node, each non-empty).
    pub nodes: Vec<Vec<usize>>,
    /// All online CPU ids, ascending.
    pub cores: Vec<usize>,
    /// SMT sibling groups: one entry per physical core listing the
    /// hardware threads sharing it (singletons when SMT is off or the
    /// sibling files are unreadable).
    pub smt_siblings: Vec<Vec<usize>>,
}

impl Topology {
    /// Single-node fallback: `cpus` CPUs, one node, no SMT info.
    pub fn single_node(cpus: usize) -> Topology {
        let cores: Vec<usize> = (0..cpus.max(1)).collect();
        Topology {
            nodes: vec![cores.clone()],
            smt_siblings: cores.iter().map(|&c| vec![c]).collect(),
            cores,
        }
    }

    /// Parse a sysfs tree rooted at `root` (normally
    /// `/sys/devices/system`; tests point this at fixture directories).
    /// Returns `None` when the CPU list is missing or malformed — the
    /// caller falls back to [`Topology::single_node`].
    pub fn from_sysfs(root: &Path) -> Option<Topology> {
        let cpu_dir = root.join("cpu");
        let cores = read_cpulist(&cpu_dir.join("online"))
            .or_else(|| read_cpulist(&cpu_dir.join("possible")))?;
        if cores.is_empty() {
            return None;
        }

        // NUMA nodes: node directories are contiguous from node0 in
        // practice; stop at the first gap. Offline/foreign CPUs are
        // dropped; empty (memory-only) nodes are skipped.
        let mut nodes: Vec<Vec<usize>> = Vec::new();
        let node_dir = root.join("node");
        let mut n = 0usize;
        loop {
            let p = node_dir.join(format!("node{n}")).join("cpulist");
            match read_cpulist(&p) {
                Some(list) => {
                    let local: Vec<usize> =
                        list.into_iter().filter(|c| cores.binary_search(c).is_ok()).collect();
                    if !local.is_empty() {
                        nodes.push(local);
                    }
                }
                None => break,
            }
            n += 1;
        }
        if nodes.is_empty() {
            nodes.push(cores.clone());
        }

        // SMT sibling groups: walk online CPUs ascending, taking each
        // CPU's thread_siblings_list the first time a member appears.
        let mut smt_siblings: Vec<Vec<usize>> = Vec::new();
        let mut grouped: Vec<usize> = Vec::new();
        for &c in &cores {
            if grouped.contains(&c) {
                continue;
            }
            let p = cpu_dir.join(format!("cpu{c}")).join("topology").join("thread_siblings_list");
            let sib: Vec<usize> = read_cpulist(&p)
                .unwrap_or_else(|| vec![c])
                .into_iter()
                .filter(|s| cores.binary_search(s).is_ok())
                .collect();
            let sib = if sib.is_empty() { vec![c] } else { sib };
            grouped.extend_from_slice(&sib);
            smt_siblings.push(sib);
        }

        Some(Topology { nodes, cores, smt_siblings })
    }

    /// Online hardware threads.
    pub fn logical_cpus(&self) -> usize {
        self.cores.len()
    }

    /// Physical cores (SMT sibling groups). This is what `auto_split`
    /// sizes worker × thread products from, so defaults stop treating
    /// hyperthreads as full cores.
    pub fn physical_cores(&self) -> usize {
        self.smt_siblings.len().max(1)
    }

    /// NUMA node count (≥ 1).
    pub fn num_nodes(&self) -> usize {
        self.nodes.len().max(1)
    }

    /// Whether any physical core exposes more than one hardware thread.
    pub fn smt(&self) -> bool {
        self.smt_siblings.iter().any(|g| g.len() > 1)
    }

    /// The node-local CPU set pool worker `id` should be pinned to:
    /// workers are spread round-robin across nodes and confined to the
    /// whole node (not one CPU), so the OS scheduler keeps freedom
    /// inside the node while cross-node migration is ruled out.
    pub fn worker_cpus(&self, id: usize) -> &[usize] {
        &self.nodes[id % self.nodes.len().max(1)]
    }
}

/// Detected host topology, computed once per process. Falls back to a
/// single node of `available_parallelism()` CPUs whenever sysfs is
/// absent or unreadable (non-Linux, sandboxed containers).
pub fn detect() -> &'static Topology {
    static TOPO: OnceLock<Topology> = OnceLock::new();
    TOPO.get_or_init(|| {
        Topology::from_sysfs(Path::new("/sys/devices/system")).unwrap_or_else(|| {
            Topology::single_node(std::thread::available_parallelism().map_or(1, |c| c.get()))
        })
    })
}

/// Read and parse one sysfs cpulist file. `None` on any I/O or parse
/// failure — callers treat that as "this part of the tree is absent".
fn read_cpulist(path: &Path) -> Option<Vec<usize>> {
    parse_cpulist(&std::fs::read_to_string(path).ok()?)
}

/// Parse the kernel cpulist format: comma-separated single ids and
/// inclusive ranges, e.g. `"0-3,8-11"` or `"0"`. Returns a sorted,
/// deduplicated list; `None` on malformed input or an empty list.
pub fn parse_cpulist(s: &str) -> Option<Vec<usize>> {
    let mut cpus: Vec<usize> = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((lo, hi)) => {
                let lo: usize = lo.trim().parse().ok()?;
                let hi: usize = hi.trim().parse().ok()?;
                if hi < lo || hi - lo > 1 << 20 {
                    return None;
                }
                cpus.extend(lo..=hi);
            }
            None => cpus.push(part.trim().parse().ok()?),
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    if cpus.is_empty() {
        None
    } else {
        Some(cpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::fs;
    use std::path::PathBuf;

    #[test]
    fn cpulist_grammar() {
        assert_eq!(parse_cpulist("0-3,8-11"), Some(vec![0, 1, 2, 3, 8, 9, 10, 11]));
        assert_eq!(parse_cpulist("0"), Some(vec![0]));
        assert_eq!(parse_cpulist("0-0\n"), Some(vec![0]));
        assert_eq!(parse_cpulist(" 2 , 1 , 1 "), Some(vec![1, 2]));
        assert_eq!(parse_cpulist(""), None);
        assert_eq!(parse_cpulist("  \n"), None);
        assert_eq!(parse_cpulist("a-b"), None);
        assert_eq!(parse_cpulist("3-1"), None);
        assert_eq!(parse_cpulist("0-4x"), None);
    }

    /// Write a fixture sysfs tree: `files` maps a path relative to the
    /// root to its contents.
    fn fixture(name: &str, files: &[(&str, &str)]) -> PathBuf {
        let root = std::env::temp_dir().join(format!("cse_topo_fixture_{name}_{}", std::process::id()));
        fs::remove_dir_all(&root).ok();
        for (rel, contents) in files {
            let p = root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(&p, contents).unwrap();
        }
        root
    }

    #[test]
    fn one_node_container_tree() {
        // A containerized host: online CPUs but no node dir and no
        // topology files — one node, singleton sibling groups.
        let root = fixture("container", &[("cpu/online", "0-3\n")]);
        let t = Topology::from_sysfs(&root).unwrap();
        assert_eq!(t.cores, vec![0, 1, 2, 3]);
        assert_eq!(t.num_nodes(), 1);
        assert_eq!(t.nodes[0], vec![0, 1, 2, 3]);
        assert_eq!(t.physical_cores(), 4);
        assert!(!t.smt());
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn two_node_tree() {
        let root = fixture(
            "two_node",
            &[
                ("cpu/online", "0-7\n"),
                ("node/node0/cpulist", "0-3\n"),
                ("node/node1/cpulist", "4-7\n"),
            ],
        );
        let t = Topology::from_sysfs(&root).unwrap();
        assert_eq!(t.num_nodes(), 2);
        assert_eq!(t.nodes[0], vec![0, 1, 2, 3]);
        assert_eq!(t.nodes[1], vec![4, 5, 6, 7]);
        assert_eq!(t.physical_cores(), 8);
        assert!(!t.smt());
        // Round-robin worker spread across nodes.
        assert_eq!(t.worker_cpus(0), &[0, 1, 2, 3]);
        assert_eq!(t.worker_cpus(1), &[4, 5, 6, 7]);
        assert_eq!(t.worker_cpus(2), &[0, 1, 2, 3]);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn smt_tree_counts_physical_cores() {
        // 8 hardware threads, 4 physical cores: siblings (0,4) (1,5) ...
        let mut files: Vec<(String, String)> = vec![
            ("cpu/online".to_string(), "0-7\n".to_string()),
            ("node/node0/cpulist".to_string(), "0-7\n".to_string()),
        ];
        for c in 0..8usize {
            files.push((
                format!("cpu/cpu{c}/topology/thread_siblings_list"),
                format!("{},{}\n", c % 4, c % 4 + 4),
            ));
        }
        let refs: Vec<(&str, &str)> =
            files.iter().map(|(a, b)| (a.as_str(), b.as_str())).collect();
        let root = fixture("smt", &refs);
        let t = Topology::from_sysfs(&root).unwrap();
        assert_eq!(t.logical_cpus(), 8);
        assert_eq!(t.physical_cores(), 4);
        assert!(t.smt());
        assert_eq!(t.smt_siblings[0], vec![0, 4]);
        assert_eq!(t.smt_siblings[3], vec![3, 7]);
        fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn missing_tree_falls_back() {
        let root = std::env::temp_dir().join("cse_topo_no_such_tree");
        assert_eq!(Topology::from_sysfs(&root), None);
        let t = Topology::single_node(6);
        assert_eq!(t.num_nodes(), 1);
        assert_eq!(t.physical_cores(), 6);
        assert!(!t.smt());
        assert_eq!(Topology::single_node(0).logical_cpus(), 1);
    }

    #[test]
    fn detect_is_stable_and_nonempty() {
        let a = detect();
        let b = detect();
        assert!(std::ptr::eq(a, b), "detect() must cache");
        assert!(a.logical_cpus() >= 1);
        assert!(a.physical_cores() >= 1);
        assert!(a.num_nodes() >= 1);
        assert!(a.nodes.iter().all(|n| !n.is_empty()));
    }
}
