//! Optional core pinning for the persistent pool's workers.
//!
//! Gated three ways, all of which must hold before a syscall is made:
//! the off-by-default `affinity` cargo feature (the default build
//! compiles the same call sites against a no-op shim), Linux on
//! x86_64/aarch64 (the only targets with a raw-syscall path — the crate
//! links no libc), and the runtime opt-in (`--pin` / [`set_pinning`]).
//!
//! Pinning confines each pool worker to the full CPU set of one NUMA
//! node ([`Topology::worker_cpus`], round-robin over nodes), pairing
//! with first-touch placement (`Csr::place` / `SellCs::place`): the
//! worker that touched a row range's pages keeps executing on the node
//! that owns them. Affinity moves threads, never loop boundaries, so it
//! is bitwise-invisible (`rust/tests/par_determinism.rs`).

use std::sync::atomic::{AtomicBool, Ordering};

static PIN_ENABLED: AtomicBool = AtomicBool::new(false);

/// Runtime pinning opt-in (the CLI's `--pin`). Takes effect for pool
/// workers spawned after the call; the CLI sets it before the first
/// parallel region, so the lazily-spawned pool sees it.
pub fn set_pinning(on: bool) {
    PIN_ENABLED.store(on, Ordering::Relaxed);
}

/// Whether pinning was requested this process.
pub fn pinning_enabled() -> bool {
    PIN_ENABLED.load(Ordering::Relaxed)
}

/// Whether this build can actually pin (feature + platform). The
/// runtime flag is independent; `--pin` on an unable build is a no-op.
pub const fn can_pin() -> bool {
    cfg!(all(
        feature = "affinity",
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))
}

/// Pin pool worker `id` to its node-local CPU set. No-op unless
/// [`can_pin`] and [`pinning_enabled`]. Failures (masked sysfs, cpuset
/// restrictions) are ignored: pinning is best-effort performance
/// policy and must never fail a job.
pub fn pin_worker(id: usize) {
    if !can_pin() || !pinning_enabled() {
        return;
    }
    let _ = pin_to_cpus(super::topo::detect().worker_cpus(id));
}

#[cfg(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
fn pin_to_cpus(cpus: &[usize]) -> Result<(), ()> {
    // A kernel cpu_set_t is 1024 bits; CPUs past that are out of scope
    // for a raw shim and are silently dropped.
    let mut mask = [0u64; 16];
    let mut any = false;
    for &c in cpus {
        if c < 1024 {
            mask[c / 64] |= 1u64 << (c % 64);
            any = true;
        }
    }
    if !any {
        return Err(());
    }
    // pid 0 = calling thread (sched_setaffinity is per-thread in Linux).
    let ret = unsafe { sched_setaffinity_raw(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    if ret == 0 {
        Ok(())
    } else {
        Err(())
    }
}

#[cfg(not(all(
    feature = "affinity",
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
)))]
fn pin_to_cpus(_cpus: &[usize]) -> Result<(), ()> {
    Ok(())
}

#[cfg(all(feature = "affinity", target_os = "linux", target_arch = "x86_64"))]
unsafe fn sched_setaffinity_raw(pid: i64, size: usize, mask: *const u64) -> i64 {
    let ret: i64;
    // syscall 203 = sched_setaffinity(pid, cpusetsize, *mask).
    core::arch::asm!(
        "syscall",
        inlateout("rax") 203i64 => ret,
        in("rdi") pid,
        in("rsi") size,
        in("rdx") mask,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

#[cfg(all(feature = "affinity", target_os = "linux", target_arch = "aarch64"))]
unsafe fn sched_setaffinity_raw(pid: i64, size: usize, mask: *const u64) -> i64 {
    let ret: i64;
    // syscall 122 = sched_setaffinity(pid, cpusetsize, *mask).
    core::arch::asm!(
        "svc 0",
        in("x8") 122i64,
        inlateout("x0") pid => ret,
        in("x1") size,
        in("x2") mask,
        options(nostack),
    );
    ret
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinning_flag_round_trips_and_pin_worker_is_safe() {
        let before = pinning_enabled();
        set_pinning(true);
        assert!(pinning_enabled());
        // Must be callable on any platform/feature combination; with the
        // feature on this also exercises the real syscall path (pinning
        // to node 0's full CPU set, which cannot wedge the test thread).
        pin_worker(0);
        pin_worker(7);
        set_pinning(false);
        assert!(!pinning_enabled());
        pin_worker(1); // disabled: no-op
        set_pinning(before);
    }
}
