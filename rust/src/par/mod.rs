//! Dependency-free parallel execution layer for the compute hot paths.
//!
//! The paper's iterations are "parallel across starting vectors"; the
//! block products they reduce to are *also* parallel across output rows.
//! This module is the one place that parallelism lives: a **persistent
//! worker pool** (no rayon — the build is offline) with deterministic
//! work partitioning, used by the SpMM kernels (`sparse::Csr`), the
//! FastEmbed recursion ([`crate::embed`]), the eigensolver baselines
//! ([`crate::eigen`]) including MGS/Lanczos reorthogonalization, SimHash
//! index builds ([`crate::index`]) and K-means ([`crate::cluster`]).
//!
//! ## Determinism contract
//!
//! Every primitive here processes a caller-supplied list of disjoint
//! `Range<usize>` chunks. Which *thread* runs a chunk is dynamic (an
//! atomic cursor hands chunks out), but what each chunk computes depends
//! only on the chunk itself, and per-chunk results are collected in chunk
//! order. Consequences:
//!
//! * Element-wise kernels (SpMM, dense matmul, K-means `nearest`) are
//!   **bitwise identical to the serial loop at any thread count**: each
//!   output row is computed by exactly the same float operations in the
//!   same order, whatever chunk it lands in.
//! * Floating-point *reductions* depend on the chunk **structure** (sums
//!   are folded chunk-by-chunk). Use [`fixed_chunks`] — a chunk count
//!   independent of the thread count — and the reduction is identical
//!   for 1, 2, … threads. Thread-dependent [`ExecPolicy::chunks`] is
//!   fine whenever no cross-row reduction happens.
//!
//! ## Pool shape
//!
//! [`ExecPolicy`] is a handle to a process-wide **persistent pool**
//! (`par::pool`): long-lived workers parked on a condvar between
//! regions, woken by a single notify per region, so a parallel region
//! costs one lock + wake instead of `threads − 1` thread spawns. The
//! policy carries the thread count plus the partitioning strategy (the
//! [`ExecPolicy::oversplit`] load-balance factor behind
//! [`ExecPolicy::chunks`]). Memory locality lives here too: [`topo`]
//! detects the host's CPU/NUMA layout from sysfs (zero deps,
//! single-node fallback), and [`affinity`] optionally pins each pool
//! worker to a node-local core set through a raw `sched_setaffinity`
//! shim behind the off-by-default `affinity` feature — std still has no
//! portable affinity API and the crate links no libc, so the default
//! build compiles the same call sites against a no-op pinner.
//! With `threads == 1` every primitive degenerates to a plain serial
//! loop with zero synchronization, spawn, or allocation overhead (the
//! CSR kernels skip partitioning entirely on their serial path), which
//! is what keeps the 1-thread path within noise of the pre-refactor
//! kernels. Pair the primitives with a [`Workspace`] to make threaded
//! steady-state iterations allocation-free too.

use std::ops::Range;

pub mod affinity;
mod cancel;
mod pool;
pub mod topo;
mod workspace;

pub use cancel::CancelToken;
pub use topo::Topology;
pub use workspace::Workspace;

/// Execution policy for a parallel region: how many OS threads to use
/// and how finely to split element-wise work. A `Copy` handle to the
/// process-wide persistent pool.
///
/// The default is serial — library callers opt in explicitly, and the
/// CLI layers default to [`ExecPolicy::auto`] (all cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecPolicy {
    /// Worker count (≥ 1). 1 = run inline on the calling thread.
    pub threads: usize,
    /// Chunk oversplit factor for thread-*dependent* partitioning
    /// ([`Self::chunks`] emits `threads × oversplit` chunks): higher
    /// values smooth load imbalance under dynamic chunk claiming at the
    /// cost of more (cheap) claims. Irrelevant to determinism — only
    /// for element-wise work in the first place.
    pub oversplit: usize,
}

impl Default for ExecPolicy {
    fn default() -> Self {
        ExecPolicy::serial()
    }
}

impl ExecPolicy {
    /// Single-threaded execution (the zero-overhead inline path).
    pub fn serial() -> Self {
        ExecPolicy { threads: 1, oversplit: 4 }
    }

    /// Exactly `threads` workers (clamped to ≥ 1).
    pub fn with_threads(threads: usize) -> Self {
        ExecPolicy { threads: threads.max(1), oversplit: 4 }
    }

    /// One worker per available hardware thread.
    pub fn auto() -> Self {
        ExecPolicy::with_threads(
            std::thread::available_parallelism().map_or(1, |n| n.get()),
        )
    }

    /// Same policy with a different [`Self::oversplit`] factor.
    pub fn with_oversplit(mut self, oversplit: usize) -> Self {
        self.oversplit = oversplit.max(1);
        self
    }

    pub fn is_serial(&self) -> bool {
        self.threads <= 1
    }

    /// Thread-*dependent* chunk count for `items` units of independent
    /// work: oversplit for load balance under dynamic chunk claiming.
    /// Only for element-wise work (no cross-item reduction) — chunk
    /// boundaries then cannot affect any output bit.
    pub fn chunks(&self, items: usize) -> usize {
        if self.threads <= 1 || items == 0 {
            1
        } else {
            (self.threads * self.oversplit.max(1)).min(items)
        }
    }

    /// Run `f(0..tasks)` with tasks handed to workers via an atomic
    /// cursor. The building block under [`Self::map_ranges`] /
    /// [`Self::map_chunks`]; use directly when chunk outputs do not fit
    /// the slice-per-range model (see `Csr::transpose_with`). Dispatches
    /// to the persistent pool; the serial path is a plain loop.
    pub fn run_indexed(&self, tasks: usize, f: impl Fn(usize) + Sync) {
        let threads = self.threads.clamp(1, tasks.max(1));
        if threads <= 1 {
            for k in 0..tasks {
                f(k);
            }
            return;
        }
        pool::run_on_pool(threads, tasks, &f);
    }

    /// Apply `f(chunk_index, range)` to every range, collecting results
    /// **in range order** (so reductions folded over the returned vec are
    /// independent of which thread ran what).
    pub fn map_ranges<R: Send>(
        &self,
        ranges: &[Range<usize>],
        f: impl Fn(usize, Range<usize>) -> R + Sync,
    ) -> Vec<R> {
        if self.threads <= 1 || ranges.len() <= 1 {
            return ranges.iter().enumerate().map(|(k, r)| f(k, r.clone())).collect();
        }
        let mut res: Vec<Option<R>> = (0..ranges.len()).map(|_| None).collect();
        let slots = SendPtr(res.as_mut_ptr());
        self.run_indexed(ranges.len(), |k| {
            let v = f(k, ranges[k].clone());
            // SAFETY: `run_indexed` hands out each k exactly once, so
            // slot k is written by exactly one thread; the buffer
            // outlives the region (we wait for completion below).
            unsafe { *slots.get().add(k) = Some(v) };
        });
        res.into_iter().map(|o| o.expect("range result missing")).collect()
    }

    /// Like [`Self::map_chunks`] but without collecting results — the
    /// zero-allocation workhorse for kernels that only write `out`
    /// (SpMM, axpy-style updates). Ranges must be ascending, contiguous,
    /// and cover `out` exactly at `width` elements per row.
    pub fn for_chunks<T: Send>(
        &self,
        ranges: &[Range<usize>],
        out: &mut [T],
        width: usize,
        f: impl Fn(usize, Range<usize>, &mut [T]) + Sync,
    ) {
        let base = ranges.first().map_or(0, |r| r.start);
        let mut cursor = base;
        for r in ranges {
            assert_eq!(r.start, cursor, "ranges must be ascending and contiguous");
            cursor = r.end;
        }
        assert_eq!((cursor - base) * width, out.len(), "ranges must cover the output exactly");
        if self.threads <= 1 || ranges.len() <= 1 {
            let mut rest = out;
            for (k, r) in ranges.iter().enumerate() {
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((r.end - r.start) * width);
                rest = tail;
                f(k, r.clone(), chunk);
            }
            debug_assert!(rest.is_empty());
            return;
        }
        let ptr = SendPtr(out.as_mut_ptr());
        self.run_indexed(ranges.len(), |k| {
            let r = ranges[k].clone();
            let off = (r.start - base) * width;
            let len = (r.end - r.start) * width;
            // SAFETY: ranges are disjoint and each k is claimed exactly
            // once, so the slices never alias; `out` outlives the region.
            let chunk = unsafe { std::slice::from_raw_parts_mut(ptr.get().add(off), len) };
            f(k, r, chunk);
        });
    }

    /// The workhorse: apply `f(chunk_index, rows, out_chunk)` to every
    /// range, where `out_chunk` is the mutable slice of `out` covering
    /// rows `r` at `width` elements per row. Ranges must be ascending,
    /// disjoint, and cover `out` exactly. Per-range results are returned
    /// in range order.
    pub fn map_chunks<T: Send, R: Send>(
        &self,
        ranges: &[Range<usize>],
        out: &mut [T],
        width: usize,
        f: impl Fn(usize, Range<usize>, &mut [T]) -> R + Sync,
    ) -> Vec<R> {
        let mut res: Vec<Option<R>> = (0..ranges.len()).map(|_| None).collect();
        let slots = SendPtr(res.as_mut_ptr());
        self.for_chunks(ranges, out, width, |k, r, chunk| {
            let v = f(k, r, chunk);
            // SAFETY: slot k is written exactly once (see for_chunks).
            unsafe { *slots.get().add(k) = Some(v) };
        });
        res.into_iter().map(|o| o.expect("chunk result missing")).collect()
    }

    /// Distribute arbitrary owned work payloads (e.g. pre-split uneven
    /// output segments) to the pool, one `f(index, payload)` call each,
    /// results in payload order. [`Self::map_chunks`] is this plus
    /// uniform-width slice splitting; kernels with non-uniform outputs
    /// (`Csr::transpose_with`) pass their own parts.
    pub fn map_parts<T: Send, R: Send>(
        &self,
        parts: Vec<T>,
        f: impl Fn(usize, T) -> R + Sync,
    ) -> Vec<R> {
        if self.threads <= 1 || parts.len() <= 1 {
            return parts.into_iter().enumerate().map(|(k, p)| f(k, p)).collect();
        }
        let n = parts.len();
        let mut parts: Vec<Option<T>> = parts.into_iter().map(Some).collect();
        let mut res: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let part_slots = SendPtr(parts.as_mut_ptr());
        let res_slots = SendPtr(res.as_mut_ptr());
        self.run_indexed(n, |k| {
            // SAFETY: each k is claimed exactly once; both buffers
            // outlive the region.
            let p = unsafe { (*part_slots.get().add(k)).take().expect("part taken twice") };
            let r = f(k, p);
            unsafe { *res_slots.get().add(k) = Some(r) };
        });
        drop(parts);
        res.into_iter().map(|o| o.expect("part result missing")).collect()
    }
}

/// Shared-pointer wrapper for disjoint per-task writes from pool workers.
/// Safety rests on the caller: every index must be touched by at most one
/// task, and the buffer must outlive the region (all primitives here wait
/// for region completion before returning).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    fn get(self) -> *mut T {
        self.0
    }
}

/// Split `s` into consecutive mutable parts of the given sizes (which
/// must sum to `s.len()`).
pub fn split_mut<T>(s: &mut [T], sizes: impl Iterator<Item = usize>) -> Vec<&mut [T]> {
    let mut rest = s;
    let mut out = Vec::new();
    for len in sizes {
        let (part, tail) = std::mem::take(&mut rest).split_at_mut(len);
        out.push(part);
        rest = tail;
    }
    assert!(rest.is_empty(), "sizes must cover the slice exactly");
    out
}

/// `items` split into `parts` contiguous near-even ranges (first
/// `items % parts` ranges get one extra). Empty ranges are never emitted.
pub fn even_ranges(items: usize, parts: usize) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    even_ranges_into(items, parts, &mut out);
    out
}

/// [`even_ranges`] into a reusable buffer (cleared first) — the
/// allocation-free form for per-iteration partitioning (see
/// [`Workspace::ranges`]).
pub fn even_ranges_into(items: usize, parts: usize, out: &mut Vec<Range<usize>>) {
    out.clear();
    if items == 0 {
        return;
    }
    let parts = parts.clamp(1, items);
    let base = items / parts;
    let extra = items % parts;
    out.reserve(parts);
    let mut start = 0;
    for k in 0..parts {
        let len = base + usize::from(k < extra);
        out.push(start..start + len);
        start += len;
    }
}

/// Ranges over `0..prefix.len()-1` balanced by the cumulative weights in
/// `prefix` (e.g. a CSR `indptr`: ranges of rows with ≈ equal nnz).
/// Deterministic for a given `prefix` and `parts`; skips empty ranges.
pub fn weighted_ranges(prefix: &[usize], parts: usize) -> Vec<Range<usize>> {
    let mut out = Vec::new();
    weighted_ranges_into(prefix, parts, &mut out);
    out
}

/// [`weighted_ranges`] into a reusable buffer (cleared first).
pub fn weighted_ranges_into(prefix: &[usize], parts: usize, out: &mut Vec<Range<usize>>) {
    out.clear();
    let n = prefix.len().saturating_sub(1);
    if n == 0 {
        return;
    }
    let total = prefix[n] - prefix[0];
    if total == 0 || parts <= 1 {
        if parts <= 1 {
            out.push(0..n);
        } else {
            even_ranges_into(n, parts, out);
        }
        return;
    }
    let parts = parts.min(n);
    out.reserve(parts);
    let mut start = 0usize;
    for k in 1..=parts {
        let target = prefix[0] + (total as u128 * k as u128 / parts as u128) as usize;
        // Smallest boundary whose prefix weight reaches the target.
        let mut end = prefix.partition_point(|&p| p < target);
        end = end.clamp(start, n);
        if k == parts {
            end = n;
        }
        if end > start {
            out.push(start..end);
            start = end;
        }
    }
}

/// Identity key of a cached (sticky) partition: what the ranges in a
/// scratch buffer were computed from. `None` = scratch holds no valid
/// partition. See [`weighted_ranges_sticky`] / [`even_ranges_sticky`].
pub type StickyKey = Option<(usize, usize, usize)>;

/// Sticky form of [`weighted_ranges_into`]: recompute the partition only
/// when `(prefix identity, prefix length, parts)` differs from what
/// `key` records, otherwise keep the cached ranges untouched.
///
/// Reuse is **bitwise-invisible**: the partitioner is a pure function of
/// `(prefix, parts)`, so a recompute would reproduce the identical
/// ranges — skipping it cannot move a chunk boundary, it only keeps the
/// partition stable across regions so each pool worker tends to stream
/// the same rows (and, after a first-touch `place`, the same pages)
/// every iteration. The prefix is identified by pointer + length, which
/// is sound because a stale match can only happen for an allocation of
/// the same shape — yielding a valid (ascending, contiguous, covering)
/// partition of the same index space either way.
pub fn weighted_ranges_sticky(
    prefix: &[usize],
    parts: usize,
    out: &mut Vec<Range<usize>>,
    key: &mut StickyKey,
) {
    let k = (prefix.as_ptr() as usize, prefix.len(), parts);
    if *key == Some(k) && !out.is_empty() {
        return;
    }
    weighted_ranges_into(prefix, parts, out);
    *key = Some(k);
}

/// Sticky form of [`even_ranges_into`] (same contract as
/// [`weighted_ranges_sticky`]; keyed by `(items, parts)` — the
/// partition is a pure function of exactly those two numbers).
pub fn even_ranges_sticky(
    items: usize,
    parts: usize,
    out: &mut Vec<Range<usize>>,
    key: &mut StickyKey,
) {
    let k = (usize::MAX, items, parts);
    if *key == Some(k) && !out.is_empty() {
        return;
    }
    even_ranges_into(items, parts, out);
    *key = Some(k);
}

/// Thread-count-INDEPENDENT chunk count: `items` split into chunks of
/// ≈ `per_chunk` rows. Use for parallel regions that fold a
/// floating-point reduction over per-chunk results — the chunk structure
/// (hence the rounding) is then fixed whatever `ExecPolicy` runs it.
pub fn fixed_chunks(items: usize, per_chunk: usize) -> usize {
    items.div_ceil(per_chunk.max(1)).max(1)
}

/// Adaptive column-shard width for `n × d` embedding jobs: the widest
/// shard such that one worker's ping-pong state (four `n × width` f64
/// blocks: result + three recurrence buffers) fits a fixed memory
/// budget, capped by a fair `d / workers` split so every worker gets
/// work, and rounded down to a multiple of the kernels' widest lane (8)
/// when there is room. Deterministic in its inputs — shard *boundaries*
/// never affect bits (each shard's columns are computed by an
/// independent serial-order recurrence), only scheduling.
pub fn adaptive_shard_width(n: usize, d: usize, workers: usize) -> usize {
    const SHARD_MEM_BUDGET: usize = 64 << 20;
    let d = d.max(1);
    // 4 blocks × 8 bytes per row, per shard column.
    let per_col = 32 * n.max(1);
    let cache_cap = (SHARD_MEM_BUDGET / per_col).max(1);
    let fair = d.div_ceil(workers.max(1));
    let w = cache_cap.min(fair).min(d).max(1);
    if w >= 8 {
        w - w % 8
    } else {
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn adaptive_shard_width_pins_representative_shapes() {
        // (n, d, workers) -> width. Hand-checked against the 64 MiB
        // budget (four n×w f64 blocks), the fair d/workers split, and
        // the round-to-lane-of-8 rule.
        for (n, d, workers, want) in [
            (100_000, 128, 4, 16), // cache cap 20 binds, rounded to lane
            (1_000_000, 64, 8, 2), // huge n: memory budget binds hard
            (10_000, 64, 4, 16),   // fair split binds, already a lane multiple
            (20_000, 64, 2, 32),   // few workers: wide shards are fine
            (100_000, 6, 16, 1),   // more workers than columns
            (50, 16, 2, 8),        // tiny n: fair split, lane width
            (0, 0, 0, 1),          // degenerate inputs clamp to 1
        ] {
            assert_eq!(
                adaptive_shard_width(n, d, workers),
                want,
                "adaptive_shard_width({n}, {d}, {workers})"
            );
        }
        // Invariants: width is in [1, max(d,1)] and the four ping-pong
        // blocks stay inside the budget.
        for n in [1usize, 1000, 250_000, 4_000_000] {
            for d in [1usize, 7, 64, 512] {
                for workers in [1usize, 3, 8, 64] {
                    let w = adaptive_shard_width(n, d, workers);
                    assert!(w >= 1 && w <= d.max(1));
                    // Width 1 is the can't-shrink-further floor; above
                    // it the blocks must fit the budget.
                    assert!(w == 1 || 32 * n.max(1) * w <= 64 << 20, "budget: n={n} d={d} w={w}");
                }
            }
        }
    }

    #[test]
    fn even_ranges_cover_and_balance() {
        for items in [0usize, 1, 7, 64, 100] {
            for parts in [1usize, 2, 3, 4, 9, 200] {
                let rs = even_ranges(items, parts);
                let mut cursor = 0;
                for r in &rs {
                    assert_eq!(r.start, cursor, "contiguous");
                    assert!(r.end > r.start, "non-empty");
                    cursor = r.end;
                }
                assert_eq!(cursor, items, "coverage for {items}/{parts}");
                if items > 0 {
                    let sizes: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
                    let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                    assert!(max - min <= 1, "balance {sizes:?}");
                }
            }
        }
    }

    #[test]
    fn ranges_into_reuses_capacity() {
        let mut buf = Vec::new();
        even_ranges_into(100, 8, &mut buf);
        assert_eq!(buf.len(), 8);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        even_ranges_into(64, 4, &mut buf);
        assert_eq!(buf.len(), 4);
        assert_eq!(buf.capacity(), cap, "no realloc on shrink");
        assert_eq!(buf.as_ptr(), ptr, "same storage");
        assert_eq!(buf, even_ranges(64, 4));
        let prefix: Vec<usize> = (0..=50).map(|i| i * 3).collect();
        weighted_ranges_into(&prefix, 5, &mut buf);
        assert_eq!(buf, weighted_ranges(&prefix, 5));
    }

    #[test]
    fn weighted_ranges_balance_by_prefix() {
        // Weights 0,0,10,0,10,1,1,... — boundaries must track weight, not rows.
        let weights = [0usize, 0, 10, 0, 10, 1, 1, 1, 1, 6];
        let mut prefix = vec![0usize];
        for w in weights {
            prefix.push(prefix.last().unwrap() + w);
        }
        for parts in [1usize, 2, 3, 4] {
            let rs = weighted_ranges(&prefix, parts);
            let mut cursor = 0;
            for r in &rs {
                assert_eq!(r.start, cursor);
                assert!(r.end > r.start);
                cursor = r.end;
            }
            assert_eq!(cursor, weights.len());
        }
        let rs = weighted_ranges(&prefix, 2);
        // Half the total weight (15) is reached inside row 4.
        assert!(rs[0].end <= 5, "first range {rs:?} should stop near the heavy rows");
    }

    #[test]
    fn sticky_partitions_reuse_until_key_changes() {
        let prefix: Vec<usize> = (0..=40).map(|i| i * i).collect();
        let mut buf = Vec::new();
        let mut key = None;
        weighted_ranges_sticky(&prefix, 4, &mut buf, &mut key);
        assert_eq!(buf, weighted_ranges(&prefix, 4));
        let ptr = buf.as_ptr();
        // Same (prefix, parts): the cached partition must be kept as-is.
        weighted_ranges_sticky(&prefix, 4, &mut buf, &mut key);
        assert_eq!(buf.as_ptr(), ptr);
        assert_eq!(buf, weighted_ranges(&prefix, 4));
        // Different parts: recompute.
        weighted_ranges_sticky(&prefix, 7, &mut buf, &mut key);
        assert_eq!(buf, weighted_ranges(&prefix, 7));
        // Different prefix (fresh allocation): recompute.
        let prefix2: Vec<usize> = (0..=25).map(|i| i * 3).collect();
        weighted_ranges_sticky(&prefix2, 7, &mut buf, &mut key);
        assert_eq!(buf, weighted_ranges(&prefix2, 7));

        // Even variant: keyed purely by (items, parts).
        let mut ekey = None;
        even_ranges_sticky(100, 8, &mut buf, &mut ekey);
        assert_eq!(buf, even_ranges(100, 8));
        let ptr = buf.as_ptr();
        even_ranges_sticky(100, 8, &mut buf, &mut ekey);
        assert_eq!(buf.as_ptr(), ptr);
        even_ranges_sticky(64, 8, &mut buf, &mut ekey);
        assert_eq!(buf, even_ranges(64, 8));
        // Zero-item partitions are never cached (the empty buffer is
        // indistinguishable from "no partition yet").
        even_ranges_sticky(0, 8, &mut buf, &mut ekey);
        assert!(buf.is_empty());
        even_ranges_sticky(0, 8, &mut buf, &mut ekey);
        assert!(buf.is_empty());
    }

    #[test]
    fn run_indexed_visits_every_task_once() {
        for threads in [1usize, 2, 4, 7] {
            let hits: Vec<AtomicUsize> = (0..57).map(|_| AtomicUsize::new(0)).collect();
            ExecPolicy::with_threads(threads)
                .run_indexed(hits.len(), |k| {
                    hits[k].fetch_add(1, Ordering::Relaxed);
                });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn map_ranges_results_in_range_order() {
        let ranges = even_ranges(40, 7);
        for threads in [1usize, 2, 4] {
            let got = ExecPolicy::with_threads(threads)
                .map_ranges(&ranges, |k, r| (k, r.start, r.end));
            for (k, (gk, s, e)) in got.iter().enumerate() {
                assert_eq!(*gk, k);
                assert_eq!((*s, *e), (ranges[k].start, ranges[k].end));
            }
        }
    }

    #[test]
    fn map_chunks_writes_disjoint_rows_identically() {
        let width = 3;
        let rows = 29;
        let want: Vec<f64> = (0..rows * width).map(|i| (i * 7 % 13) as f64).collect();
        for threads in [1usize, 2, 4] {
            for parts in [1usize, 2, 5, 29] {
                let ranges = even_ranges(rows, parts);
                let mut out = vec![0.0f64; rows * width];
                let sums = ExecPolicy::with_threads(threads).map_chunks(
                    &ranges,
                    &mut out,
                    width,
                    |_, r, chunk| {
                        let mut s = 0.0;
                        for (local, i) in r.enumerate() {
                            for j in 0..width {
                                let v = ((i * width + j) * 7 % 13) as f64;
                                chunk[local * width + j] = v;
                                s += v;
                            }
                        }
                        s
                    },
                );
                assert_eq!(out, want, "threads={threads} parts={parts}");
                assert_eq!(sums.len(), ranges.len());
            }
        }
    }

    #[test]
    fn for_chunks_matches_map_chunks_output() {
        let rows = 37;
        let width = 2;
        let ranges = even_ranges(rows, 6);
        let fill = |_: usize, r: Range<usize>, chunk: &mut [f64]| {
            for (local, i) in r.enumerate() {
                for j in 0..width {
                    chunk[local * width + j] = (i * width + j) as f64;
                }
            }
        };
        let mut want = vec![0.0; rows * width];
        ExecPolicy::serial().for_chunks(&ranges, &mut want, width, fill);
        for threads in [2usize, 4] {
            let mut got = vec![0.0; rows * width];
            ExecPolicy::with_threads(threads).for_chunks(&ranges, &mut got, width, fill);
            assert_eq!(got, want, "{threads} threads");
        }
    }

    #[test]
    fn map_parts_returns_in_payload_order() {
        for threads in [1usize, 2, 4] {
            let parts: Vec<usize> = (0..23).collect();
            let got = ExecPolicy::with_threads(threads).map_parts(parts, |k, p| {
                assert_eq!(k, p);
                p * 10
            });
            assert_eq!(got, (0..23).map(|p| p * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn fixed_chunk_reduction_is_thread_count_independent() {
        // Adversarially scaled values: naive full-serial summation differs
        // from chunked summation, so equality across thread counts proves
        // the chunk structure (not the schedule) fixes the rounding.
        let n = 10_000;
        let vals: Vec<f64> = (0..n).map(|i| ((i % 97) as f64 - 48.0) * 1e-3 + 1e9).collect();
        let ranges = even_ranges(n, fixed_chunks(n, 1024));
        let sum_at = |threads: usize| -> f64 {
            ExecPolicy::with_threads(threads)
                .map_ranges(&ranges, |_, r| vals[r].iter().sum::<f64>())
                .iter()
                .sum()
        };
        let s1 = sum_at(1);
        assert_eq!(s1.to_bits(), sum_at(2).to_bits());
        assert_eq!(s1.to_bits(), sum_at(4).to_bits());
    }

    #[test]
    fn split_mut_partitions_exactly() {
        let mut v: Vec<u32> = (0..10).collect();
        let parts = split_mut(&mut v, [3usize, 0, 4, 3].into_iter());
        assert_eq!(parts.len(), 4);
        assert_eq!(&parts[0][..], &[0, 1, 2][..]);
        assert!(parts[1].is_empty());
        assert_eq!(&parts[3][..], &[7, 8, 9][..]);
    }

    #[test]
    fn auto_and_serial_policies() {
        assert!(ExecPolicy::auto().threads >= 1);
        assert!(ExecPolicy::serial().is_serial());
        assert_eq!(ExecPolicy::with_threads(0).threads, 1);
        assert_eq!(ExecPolicy::default(), ExecPolicy::serial());
        // Oversplit shapes thread-dependent chunking only.
        let p = ExecPolicy::with_threads(4).with_oversplit(2);
        assert_eq!(p.chunks(1000), 8);
        assert_eq!(ExecPolicy::with_threads(4).chunks(1000), 16);
        assert_eq!(p.chunks(3), 3);
        assert_eq!(ExecPolicy::serial().chunks(1000), 1);
    }
}
