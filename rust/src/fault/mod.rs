//! Deterministic fault injection for chaos testing.
//!
//! A process-global failpoint registry. Call sites name themselves with
//! a string key and call [`inject`]; the registry decides — from an
//! armed spec and a deterministic counter-seeded draw — whether that
//! site should panic, sleep, or report that the caller must poison its
//! own data. Disarmed (the default), [`inject`] is a single relaxed
//! atomic load, the same cost model as `obs::stats_enabled()`, so
//! production hot paths pay nothing.
//!
//! # Spec grammar
//!
//! A spec is a comma-separated list of rules:
//!
//! ```text
//! site:kind[:p=P][:seed=N][:ms=N]
//! ```
//!
//! * `site` — failpoint name; current sites are `shard_run` (fires once
//!   per shard attempt in the coordinator) and `pool_task` (fires once
//!   per worker claim loop in the persistent pool).
//! * `kind` — `panic` (unwinds with a tagged message), `delay` (sleeps
//!   `ms` milliseconds, default 5), or `poison` (the call site corrupts
//!   its own freshly computed data with a NaN, exercising the numerical
//!   guards).
//! * `p` — injection probability in `[0, 1]`, default 1.
//! * `seed` — seed for the deterministic draw, default 0.
//!
//! Example: `--fault-spec 'shard_run:panic:p=0.3:seed=7'`.
//!
//! Draws are `splitmix64(seed ⊕ f(draw_index, site))` — a pure function
//! of the spec and the per-registry draw counter, never of wall clock or
//! OS entropy, so a single-threaded run replays identically. Under
//! concurrency the draw *order* varies with scheduling, but the
//! fault-tolerance contract under test is stronger than replayed faults:
//! embedding output must be bitwise identical to the fault-free run for
//! **any** injection pattern, because every injected failure is caught
//! and the shard deterministically re-executed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// What an armed rule does when its draw succeeds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Unwind with a `fault injected: <site> panic` message.
    Panic,
    /// Sleep for the given number of milliseconds.
    Delay(u64),
    /// Returned to the caller, which scribbles a NaN into its own
    /// output to exercise downstream numerical guards.
    Poison,
}

#[derive(Clone, Debug)]
struct Rule {
    site: String,
    kind: FaultKind,
    p: f64,
    seed: u64,
}

/// Fast-path gate: one relaxed load when disarmed.
static ARMED: AtomicBool = AtomicBool::new(false);
static RULES: Mutex<Vec<Rule>> = Mutex::new(Vec::new());
/// Monotone draw counter; reset on (re-)arm so a given spec replays.
static DRAWS: AtomicU64 = AtomicU64::new(0);

/// Environment variable consulted by the CLI when `--fault-spec` is
/// absent.
pub const ENV_SPEC: &str = "CSE_FAULT_SPEC";

fn rules() -> MutexGuard<'static, Vec<Rule>> {
    // An injected panic can unwind while a caller holds this lock in a
    // test harness; treat poison as recoverable — the data is a plain
    // rule list that no panic leaves half-written.
    RULES.lock().unwrap_or_else(|p| p.into_inner())
}

/// Arm the registry with a spec (see module docs for the grammar).
/// Replaces any previous spec and resets the draw counter.
pub fn arm(spec: &str) -> Result<(), String> {
    let parsed = parse(spec)?;
    let mut g = rules();
    *g = parsed;
    DRAWS.store(0, Ordering::Relaxed);
    ARMED.store(true, Ordering::Release);
    Ok(())
}

/// Disarm and clear every rule; [`inject`] returns to its one-load
/// fast path.
pub fn disarm() {
    ARMED.store(false, Ordering::Release);
    rules().clear();
}

/// Whether any spec is armed.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Total faults injected since process start (all kinds, all sites).
pub fn injected() -> u64 {
    crate::obs::failstats::FAULTS_INJECTED.load(Ordering::Relaxed)
}

/// Evaluate the failpoint `site`. Disarmed this is one relaxed load.
/// Armed, a successful draw either panics or sleeps here, or returns
/// `Some(FaultKind::Poison)` for the caller to act on; `None` means
/// "no fault this time".
#[inline]
pub fn inject(site: &str) -> Option<FaultKind> {
    if !armed() {
        return None;
    }
    inject_slow(site)
}

#[cold]
fn inject_slow(site: &str) -> Option<FaultKind> {
    let kind = {
        let g = rules();
        let rule = g.iter().find(|r| r.site == site)?;
        // Count draws only for matching sites so rule evaluation order
        // elsewhere cannot shift this rule's sequence.
        let n = DRAWS.fetch_add(1, Ordering::Relaxed);
        if rule.p < 1.0 {
            let h = splitmix64(rule.seed ^ n.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ site_hash(site));
            let u = (h >> 11) as f64 / (1u64 << 53) as f64;
            if u >= rule.p {
                return None;
            }
        }
        rule.kind
        // Lock dropped here, before any panic below.
    };
    crate::obs::failstats::FAULTS_INJECTED.fetch_add(1, Ordering::Relaxed);
    match kind {
        FaultKind::Panic => panic!("fault injected: {site} panic"),
        FaultKind::Delay(ms) => std::thread::sleep(std::time::Duration::from_millis(ms)),
        FaultKind::Poison => {}
    }
    Some(kind)
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

fn site_hash(s: &str) -> u64 {
    // FNV-1a; only needs to decorrelate sites sharing a seed.
    s.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3))
}

fn parse(spec: &str) -> Result<Vec<Rule>, String> {
    let mut out = Vec::new();
    for raw in spec.split(',') {
        let raw = raw.trim();
        if raw.is_empty() {
            continue;
        }
        let mut parts = raw.split(':');
        let site = parts.next().unwrap_or("").trim();
        if site.is_empty() || site.contains('=') {
            return Err(format!("fault rule '{raw}': expected 'site:kind[:p=..][:seed=..][:ms=..]'"));
        }
        let kind_name = parts.next().unwrap_or("").trim();
        let mut p = 1.0f64;
        let mut seed = 0u64;
        let mut ms = 5u64;
        for kv in parts {
            let kv = kv.trim();
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| format!("fault rule '{raw}': bad parameter '{kv}' (want k=v)"))?;
            match key.trim() {
                "p" => {
                    p = val
                        .trim()
                        .parse::<f64>()
                        .ok()
                        .filter(|p| (0.0..=1.0).contains(p))
                        .ok_or_else(|| format!("fault rule '{raw}': p must be in [0,1], got '{val}'"))?;
                }
                "seed" => {
                    seed = val
                        .trim()
                        .parse()
                        .map_err(|_| format!("fault rule '{raw}': bad seed '{val}'"))?;
                }
                "ms" => {
                    ms = val
                        .trim()
                        .parse()
                        .map_err(|_| format!("fault rule '{raw}': bad ms '{val}'"))?;
                }
                other => return Err(format!("fault rule '{raw}': unknown parameter '{other}'")),
            }
        }
        let kind = match kind_name {
            "panic" => FaultKind::Panic,
            "delay" => FaultKind::Delay(ms),
            "poison" => FaultKind::Poison,
            other => {
                return Err(format!(
                    "fault rule '{raw}': unknown kind '{other}' (want panic|delay|poison)"
                ))
            }
        };
        out.push(Rule { site: site.to_string(), kind, p, seed });
    }
    if out.is_empty() {
        return Err("empty fault spec".to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; serialize the tests that arm it.
    // Sites used here are private to this module so armed windows never
    // interfere with coordinator/pool tests in the same binary.
    static LOCK: Mutex<()> = Mutex::new(());

    fn serialize() -> MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "   ",
            "shard_run",
            "shard_run:explode",
            "shard_run:panic:p=1.5",
            "shard_run:panic:p=nan",
            "shard_run:panic:q=1",
            "shard_run:panic:seed=x",
            "p=0.5:panic",
        ] {
            assert!(parse(bad).is_err(), "spec {bad:?} should be rejected");
        }
    }

    #[test]
    fn parse_accepts_the_documented_grammar() {
        let rules =
            parse("shard_run:panic:p=0.3:seed=7, pool_task:delay:ms=2,x:poison").unwrap();
        assert_eq!(rules.len(), 3);
        assert_eq!(rules[0].site, "shard_run");
        assert_eq!(rules[0].kind, FaultKind::Panic);
        assert!((rules[0].p - 0.3).abs() < 1e-12);
        assert_eq!(rules[0].seed, 7);
        assert_eq!(rules[1].kind, FaultKind::Delay(2));
        assert_eq!(rules[2].kind, FaultKind::Poison);
        assert_eq!(rules[2].p, 1.0);
    }

    #[test]
    fn certain_panic_fires_and_is_catchable() {
        let _g = serialize();
        arm("fault_test_panic:panic").unwrap();
        let before = injected();
        let r = std::panic::catch_unwind(|| inject("fault_test_panic"));
        disarm();
        let payload = r.expect_err("p=1 panic rule must fire");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("fault injected: fault_test_panic panic"), "got {msg:?}");
        assert!(injected() > before, "injection counter must advance");
    }

    #[test]
    fn unmatched_sites_and_disarmed_registry_are_silent() {
        let _g = serialize();
        arm("fault_test_other:poison").unwrap();
        assert_eq!(inject("fault_test_nomatch"), None);
        disarm();
        assert_eq!(inject("fault_test_other"), None);
        assert!(!armed());
    }

    #[test]
    fn draws_replay_deterministically_after_rearm() {
        let _g = serialize();
        let draw_sequence = || {
            arm("fault_test_seq:poison:p=0.5:seed=42").unwrap();
            let seq: Vec<bool> =
                (0..64).map(|_| inject("fault_test_seq").is_some()).collect();
            disarm();
            seq
        };
        let a = draw_sequence();
        let b = draw_sequence();
        assert_eq!(a, b, "same spec must replay the same draw sequence");
        let hits = a.iter().filter(|&&h| h).count();
        assert!(hits > 10 && hits < 54, "p=0.5 over 64 draws, got {hits} hits");
    }

    #[test]
    fn poison_is_returned_to_the_caller() {
        let _g = serialize();
        arm("fault_test_poison:poison").unwrap();
        assert_eq!(inject("fault_test_poison"), Some(FaultKind::Poison));
        disarm();
    }
}
