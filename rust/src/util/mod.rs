//! Shared substrate utilities.
//!
//! The offline build environment vendors only the `xla` crate closure, so
//! everything a normal project would pull from crates.io lives here:
//! a seedable PRNG ([`rng`]), order statistics ([`stats`]), wall-clock
//! timers ([`timer`]), a minimal CLI argument parser ([`args`]) and a
//! minimal JSON parser ([`json`]) for the artifact manifest.

pub mod args;
pub mod json;
pub mod rng;
pub mod stats;
pub mod timer;

/// Human-readable byte count.
pub fn human_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(0.5), "500.00 ms");
        assert_eq!(human_secs(2.0), "2.00 s");
        assert_eq!(human_secs(300.0), "5.0 min");
    }
}
