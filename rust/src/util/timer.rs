//! Wall-clock timing helpers for the bench harness (criterion is not
//! available offline; `rust/benches/` builds on these).

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Measurement summary produced by [`bench`].
#[derive(Clone, Debug)]
pub struct Sample {
    pub iters: usize,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// Micro-bench: warm up once, then run `iters` timed iterations.
pub fn bench<T>(iters: usize, mut f: impl FnMut() -> T) -> Sample {
    std::hint::black_box(f()); // warm-up
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        std::hint::black_box(f());
        times.push(t.elapsed_secs());
    }
    let mean = times.iter().sum::<f64>() / iters.max(1) as f64;
    Sample {
        iters,
        mean_secs: mean,
        min_secs: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_secs: times.iter().cloned().fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_value_and_positive_duration() {
        let (v, secs) = time(|| (0..1000).sum::<usize>());
        assert_eq!(v, 499500);
        assert!(secs >= 0.0);
    }

    #[test]
    fn bench_collects_iters() {
        let s = bench(5, || std::hint::black_box(1 + 1));
        assert_eq!(s.iters, 5);
        assert!(s.min_secs <= s.mean_secs && s.mean_secs <= s.max_secs + 1e-12);
    }
}
