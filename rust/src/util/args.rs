//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    named: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// Declared option, for usage rendering.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `bool_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, bool_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.named.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        return Err(format!("option --{rest} expects a value"));
                    }
                    out.named.insert(rest.to_string(), it.next().unwrap());
                } else {
                    return Err(format!("option --{rest} expects a value"));
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env(bool_flags: &[&str]) -> Result<Args, String> {
        Args::parse(std::env::args().skip(1), bool_flags)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.named.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad usize '{v}'")),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad u64 '{v}'")),
        }
    }

    pub fn f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: bad f64 '{v}'")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Keys that were provided but are not in `known` — for typo detection.
    pub fn unknown_keys(&self, known: &[&str]) -> Vec<String> {
        self.named
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }
}

/// Render a usage block from declared options.
pub fn usage(cmd: &str, about: &str, opts: &[Opt]) -> String {
    let mut s = format!("{about}\n\nUsage: {cmd} [options]\n\nOptions:\n");
    for o in opts {
        let def = o.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
        s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, def));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--n", "100", "--d=8", "pos1"], &[]);
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get("d"), Some("8"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["--n", "100", "--eps", "0.25"], &[]);
        assert_eq!(a.usize("n", 5).unwrap(), 100);
        assert_eq!(a.usize("missing", 7).unwrap(), 7);
        assert!((a.f64("eps", 0.0).unwrap() - 0.25).abs() < 1e-12);
        assert!(a.usize("eps", 0).is_err());
    }

    #[test]
    fn boolean_flags() {
        let a = parse(&["--verbose", "--n", "3"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(["--n".to_string()], &[]).is_err());
        assert!(Args::parse(["--n".to_string(), "--m".to_string()], &[]).is_err());
    }

    #[test]
    fn unknown_key_detection() {
        let a = parse(&["--typo", "1"], &[]);
        assert_eq!(a.unknown_keys(&["n", "d"]), vec!["typo".to_string()]);
    }
}
