//! Order statistics & summaries used by the benchmark harness and the
//! figure-regeneration code (the paper reports percentile curves).

/// Percentile with linear interpolation (like `numpy.percentile`).
/// `p` in `[0, 100]`. Returns NaN on empty input.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 100.0);
    let idx = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    let frac = idx - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sorts in place and returns the requested percentiles.
pub fn percentiles(values: &mut [f64], ps: &[f64]) -> Vec<f64> {
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ps.iter().map(|&p| percentile(values, p)).collect()
}

/// Mean of a slice (NaN on empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (NaN for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&v, 50.0)
}

/// Online histogram over fixed uniform bins — used to bucket "exact
/// correlation" values when regenerating Figure 1b.
#[derive(Clone, Debug)]
pub struct Binner {
    lo: f64,
    hi: f64,
    bins: Vec<Vec<f64>>,
}

impl Binner {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Binner { lo, hi, bins: vec![Vec::new(); nbins] }
    }

    /// Place `value` into the bin that `key` falls in (clamped).
    pub fn add(&mut self, key: f64, value: f64) {
        let n = self.bins.len();
        let t = ((key - self.lo) / (self.hi - self.lo) * n as f64).floor();
        let idx = (t as isize).clamp(0, n as isize - 1) as usize;
        self.bins[idx].push(value);
    }

    pub fn bin_center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) * (self.hi - self.lo) / self.bins.len() as f64
    }

    pub fn bins(&self) -> &[Vec<f64>] {
        &self.bins
    }

    pub fn bins_mut(&mut self) -> &mut [Vec<f64>] {
        &mut self.bins
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_interpolates() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&v, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_degenerate() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn mean_std_median() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
        assert!((median(&xs) - 4.5).abs() < 1e-12);
    }

    #[test]
    fn binner_routes_and_clamps() {
        let mut b = Binner::new(0.0, 1.0, 4);
        b.add(0.1, 10.0);
        b.add(0.9, 20.0);
        b.add(-5.0, 30.0); // clamped into bin 0
        b.add(5.0, 40.0); // clamped into last bin
        assert_eq!(b.bins()[0], vec![10.0, 30.0]);
        assert_eq!(b.bins()[3], vec![20.0, 40.0]);
        assert!((b.bin_center(0) - 0.125).abs() < 1e-12);
    }
}
