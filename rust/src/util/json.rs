//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Parses the artifact `manifest.json` written by `python/compile/aot.py`
//! and serializes benchmark results. Supports the full JSON grammar except
//! `\u` surrogate pairs are passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| x.fract() == 0.0 && *x >= 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&String> {
        match self {
            Json::Obj(m) => m.keys().collect(),
            _ => Vec::new(),
        }
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e2 ").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"params":[[256,256],[256,32]],"dtype":"f32","n":256,"x":1.5}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
