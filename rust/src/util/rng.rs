//! Seedable PRNG: SplitMix64 stream-splitting + Xoshiro256++ core.
//!
//! crates.io `rand` is unavailable offline; this is the standard public-
//! domain construction (Blackman & Vigna). Every stochastic component in
//! the library (JL projections, graph generators, k-means seeding, property
//! tests) takes an explicit [`Rng`] so all experiments are reproducible
//! from a single `--seed`.

/// SplitMix64 — used to expand a user seed into Xoshiro state and to derive
/// independent child streams (`Rng::split`).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single `u64`.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix of any seed never
        // produces four zeros, but guard anyway.
        if s == [0; 4] {
            s[0] = 0x1;
        }
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child stream (for per-worker/per-shard RNGs).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free enough for
    /// our n << 2^64 use; uses 128-bit multiply).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Fair coin.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Rademacher ±1.
    #[inline]
    pub fn rademacher(&mut self) -> f64 {
        if self.bool() {
            1.0
        } else {
            -1.0
        }
    }

    /// Standard normal via Box–Muller (caches the spare).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean {m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var {m2}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        for _ in 0..50 {
            let s = r.sample_indices(20, 10);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 10);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let mut root = Rng::new(8);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn rademacher_balanced() {
        let mut r = Rng::new(9);
        let s: f64 = (0..100_000).map(|_| r.rademacher()).sum();
        assert!(s.abs() < 1500.0, "{s}");
    }
}
