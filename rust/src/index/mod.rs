//! Sublinear similarity serving: ANN indexes over the embedding rows.
//!
//! The embedding exists so that downstream inference can be answered from
//! pairwise ℓ₂/correlation geometry alone (§1) — but the serving layer
//! still answered every top-k query with an `O(n·d)` linear scan. This
//! module continues the paper's compressive idea one layer up: a
//! sign-random-projection (SimHash) index whose Hamming distance between
//! ±1 hyperplane signatures estimates exactly the normalized correlation
//! the embedding was built to preserve, so candidate generation is
//! sublinear and only a small candidate set is re-ranked exactly.
//!
//! * [`AnnIndex`] — the trait the service routes `Query::TopK` through.
//!   Indexes are pure acceleration structures: they never own the
//!   embedding, the service passes `(e, norms)` at query time, and the
//!   exact scan remains the oracle.
//! * [`exact`] — the exact-scan baseline behind the trait (the previous
//!   `SimilarityService::top_k` behaviour).
//! * [`simhash`] — multi-table SimHash LSH: `tables × bits` hyperplane
//!   signatures, banded bucket maps, multi-probe candidate generation
//!   (flip low-margin bits), exact correlation re-ranking.
//! * [`recall`] — recall@k evaluation harness comparing any index against
//!   the exact scan.

pub mod exact;
pub mod recall;
pub mod simhash;

pub use exact::ExactIndex;
pub use recall::{evaluate_recall, RecallReport};
pub use simhash::{SimHashIndex, SimHashParams};

use crate::linalg::Mat;

/// An answered top-k query plus how much work it took.
#[derive(Clone, Debug, PartialEq)]
pub struct TopK {
    /// `(vertex, correlation)` pairs, best first; ties broken by lower id.
    pub hits: Vec<(usize, f64)>,
    /// Rows whose exact correlation was evaluated to produce `hits`.
    pub candidates: usize,
}

/// Approximate-nearest-neighbour index over the rows of an embedding.
pub trait AnnIndex: Send + Sync {
    /// Short name for CLI / bench reporting (`"exact"`, `"simhash"`, …).
    fn name(&self) -> &'static str;

    /// Number of indexed rows; must equal the served embedding's rows.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Top-k most correlated rows to row `i` (excluding `i` itself),
    /// ordered by `(correlation desc, id asc)`.
    fn top_k(&self, e: &Mat, norms: &[f64], i: usize, k: usize) -> TopK;

    /// Auxiliary memory held by the index (excludes the embedding).
    fn mem_bytes(&self) -> usize;
}

/// Normalized correlation of rows `i`, `j` given precomputed norms
/// (0 for near-zero rows, matching `Mat::row_corr`).
#[inline]
pub fn row_corr(e: &Mat, norms: &[f64], i: usize, j: usize) -> f64 {
    let (ni, nj) = (norms[i], norms[j]);
    if ni < 1e-300 || nj < 1e-300 {
        return 0.0;
    }
    e.row_dot(i, j) / (ni * nj)
}

/// Precompute row norms for [`row_corr`] / [`rerank_top_k`].
pub fn row_norms(e: &Mat) -> Vec<f64> {
    (0..e.rows).map(|i| e.row_norm(i)).collect()
}

/// `(id, corr)` ranking order: higher correlation first, ties broken by
/// lower id — the deterministic order every top-k path in the crate uses,
/// so exact and indexed answers are comparable element-wise.
#[inline]
pub fn ranks_before(a: (usize, f64), b: (usize, f64)) -> bool {
    a.1 > b.1 || (a.1 == b.1 && a.0 < b.0)
}

/// Exact-correlation re-ranking shared by every index: scan `candidates`,
/// keep the `k` best by `(correlation desc, id asc)`. `candidates` must
/// not repeat ids (dedup before calling) and may include `i` (skipped).
pub fn rerank_top_k(
    e: &Mat,
    norms: &[f64],
    i: usize,
    k: usize,
    candidates: impl IntoIterator<Item = usize>,
) -> Vec<(usize, f64)> {
    if k == 0 {
        return Vec::new();
    }
    let _span = crate::obs::span(&crate::obs::QUERY_RERANK);
    // Kept sorted best-first; bounded insertion keeps each step O(k).
    let mut best: Vec<(usize, f64)> = Vec::with_capacity(k.min(e.rows) + 1);
    for j in candidates {
        if j == i {
            continue;
        }
        let cand = (j, row_corr(e, norms, i, j));
        if best.len() == k {
            if !ranks_before(cand, *best.last().unwrap()) {
                continue;
            }
            best.pop();
        }
        let pos = best.partition_point(|&p| ranks_before(p, cand));
        best.insert(pos, cand);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn exhaustive(e: &Mat, norms: &[f64], i: usize, k: usize) -> Vec<(usize, f64)> {
        let mut all: Vec<(usize, f64)> = (0..e.rows)
            .filter(|&j| j != i)
            .map(|j| (j, row_corr(e, norms, i, j)))
            .collect();
        all.sort_by(|&a, &b| {
            b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
        });
        all.truncate(k);
        all
    }

    #[test]
    fn rerank_matches_exhaustive_sort() {
        let mut rng = Rng::new(71);
        let e = Mat::randn(&mut rng, 60, 5);
        let norms = row_norms(&e);
        for &i in &[0, 13, 59] {
            for &k in &[1, 4, 10, 59, 80] {
                let got = rerank_top_k(&e, &norms, i, k, 0..e.rows);
                assert_eq!(got, exhaustive(&e, &norms, i, k), "i={i} k={k}");
            }
        }
    }

    #[test]
    fn rerank_breaks_ties_by_id() {
        // Duplicate rows → exact correlation ties; lower id must win.
        let e = Mat::from_rows(&[
            &[1.0, 0.0],
            &[2.0, 0.0],
            &[3.0, 0.0],
            &[0.0, 1.0],
        ]);
        let norms = row_norms(&e);
        let got = rerank_top_k(&e, &norms, 0, 2, 0..4);
        assert_eq!(got.iter().map(|p| p.0).collect::<Vec<_>>(), vec![1, 2]);
        // Same query with candidates in reverse order: identical answer.
        let rev = rerank_top_k(&e, &norms, 0, 2, (0..4).rev());
        assert_eq!(got, rev);
    }

    #[test]
    fn rerank_k_zero_and_k_large() {
        let mut rng = Rng::new(72);
        let e = Mat::randn(&mut rng, 5, 3);
        let norms = row_norms(&e);
        assert!(rerank_top_k(&e, &norms, 0, 0, 0..5).is_empty());
        assert_eq!(rerank_top_k(&e, &norms, 0, 100, 0..5).len(), 4);
    }

    #[test]
    fn row_corr_matches_mat() {
        let mut rng = Rng::new(73);
        let e = Mat::randn(&mut rng, 12, 4);
        let norms = row_norms(&e);
        for i in 0..12 {
            for j in 0..12 {
                assert!((row_corr(&e, &norms, i, j) - e.row_corr(i, j)).abs() < 1e-12);
            }
        }
    }
}
