//! Multi-table SimHash (sign-random-projection) LSH over embedding rows.
//!
//! Each of `tables` hash tables draws `bits` random Gaussian hyperplanes
//! in R^d; a row's signature packs the projection signs into a `u64`.
//! For unit-norm rows, `P[bit agrees] = 1 − θ/π` where θ is the angle
//! between the rows — Hamming distance between signatures is an unbiased
//! estimator of exactly the normalized correlation the compressive
//! embedding preserves (§1), which is why SimHash composes with it so
//! cleanly: signatures are invariant to positive row rescaling, as is
//! the correlation itself.
//!
//! Querying is multi-probe (Lv et al., VLDB 2007): besides the query's
//! own bucket, each table probes the buckets reached by flipping the
//! lowest-|margin| signature bits — the bits whose hyperplane projection
//! was closest to zero and therefore most likely to disagree for a true
//! neighbour. Probe masks are enumerated in increasing total flipped
//! margin with a heap, so `probes = 2^bits` degenerates to scanning the
//! whole table (and the index provably returns the exact answer).
//! Candidates from all tables are deduped and re-ranked by exact
//! correlation, so answers use true scores — the index only decides
//! *which* rows get scored.

use std::collections::{BinaryHeap, HashMap};

use super::{rerank_top_k, AnnIndex, TopK};
use crate::linalg::Mat;
use crate::par::{self, ExecPolicy};
use crate::util::rng::Rng;

/// SimHash index parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimHashParams {
    /// Independent hash tables; more tables → higher recall, more memory.
    pub tables: usize,
    /// Signature bits per table (1..=64); more bits → smaller buckets.
    pub bits: usize,
    /// Buckets probed per table (≥ 1; includes the query's own bucket).
    pub probes: usize,
    /// Hyperplane RNG seed (independent of the embedding seed).
    pub seed: u64,
    /// Build-time threading (signature hashing + bucket maps). Queries
    /// are parallelized at the service layer instead. The built index is
    /// identical at any thread count.
    pub exec: ExecPolicy,
}

impl Default for SimHashParams {
    fn default() -> Self {
        // Tuned on SBM serving workloads: recall@10 ≳ 0.95 while scanning
        // well under 10% of rows at n = 1e5 (see benches `serving`).
        SimHashParams {
            tables: 8,
            bits: 12,
            probes: 16,
            seed: 0xC5E_51E_D,
            exec: ExecPolicy::serial(),
        }
    }
}

/// The built index: hyperplanes + per-table bucket maps.
pub struct SimHashIndex {
    pub params: SimHashParams,
    n: usize,
    d: usize,
    /// `(tables*bits) × d` Gaussian hyperplanes; table `t` owns rows
    /// `t*bits .. (t+1)*bits`.
    planes: Mat,
    /// Per table: signature → indexed row ids.
    buckets: Vec<HashMap<u64, Vec<u32>>>,
    /// Wall-clock seconds spent in `build` (reported by the CLI).
    pub build_secs: f64,
}

impl SimHashIndex {
    /// Hash every row of `e` into `tables` bucket maps.
    pub fn build(e: &Mat, params: SimHashParams) -> SimHashIndex {
        assert!(params.tables >= 1, "tables must be >= 1");
        assert!(
            (1..=64).contains(&params.bits),
            "bits must be in 1..=64 (signatures are packed u64s)"
        );
        assert!(params.probes >= 1, "probes must be >= 1");
        assert!(e.rows <= u32::MAX as usize, "row ids are stored as u32");
        let t = crate::util::timer::Timer::start();
        let mut rng = Rng::new(params.seed);
        let planes = Mat::randn(&mut rng, params.tables * params.bits, e.cols);
        let (tables, bits, exec) = (params.tables, params.bits, &params.exec);
        // Pass 1: packed per-row signatures, row-partitioned across the
        // pool (the n·tables·bits·d hot loop of the build).
        let mut sigs = vec![0u64; e.rows * tables];
        let ranges = par::even_ranges(e.rows, exec.chunks(e.rows));
        exec.map_chunks(&ranges, &mut sigs, tables, |_, rows, out| {
            let mut projs = vec![0.0; tables * bits];
            for (local, i) in rows.enumerate() {
                project_into(&planes, e.row(i), &mut projs);
                for tbl in 0..tables {
                    out[local * tables + tbl] =
                        pack_signs(&projs[tbl * bits..(tbl + 1) * bits]);
                }
            }
        });
        // Pass 2: bucket maps, partitioned across tables. Every map
        // inserts row ids in ascending order exactly like a serial scan,
        // so the built index is thread-count-independent.
        let mut buckets: Vec<HashMap<u64, Vec<u32>>> =
            (0..tables).map(|_| HashMap::new()).collect();
        let tranges = par::even_ranges(tables, exec.threads.min(tables));
        exec.map_chunks(&tranges, &mut buckets, 1, |_, trange, maps| {
            for (local, tbl) in trange.enumerate() {
                let map = &mut maps[local];
                for i in 0..e.rows {
                    map.entry(sigs[i * tables + tbl]).or_default().push(i as u32);
                }
            }
        });
        SimHashIndex { params, n: e.rows, d: e.cols, planes, buckets, build_secs: t.elapsed_secs() }
    }

    /// Per-table signatures of an arbitrary vector (diagnostics/tests).
    pub fn signatures(&self, row: &[f64]) -> Vec<u64> {
        assert_eq!(row.len(), self.d);
        let mut projs = vec![0.0; self.params.tables * self.params.bits];
        project_into(&self.planes, row, &mut projs);
        (0..self.params.tables)
            .map(|t| pack_signs(&projs[t * self.params.bits..(t + 1) * self.params.bits]))
            .collect()
    }

    /// Deduplicated candidate ids for a query row (multi-probe across all
    /// tables). An indexed query row is always among its own candidates;
    /// re-ranking skips self-matches.
    pub fn candidates(&self, row: &[f64]) -> Vec<usize> {
        assert_eq!(row.len(), self.d);
        let bits = self.params.bits;
        let mut projs = vec![0.0; self.params.tables * bits];
        {
            let _span = crate::obs::span(&crate::obs::QUERY_HASH);
            project_into(&self.planes, row, &mut projs);
        }
        let mut out: Vec<u32> = Vec::new();
        {
            let _span = crate::obs::span(&crate::obs::QUERY_PROBE);
            for (tbl, map) in self.buckets.iter().enumerate() {
                let z = &projs[tbl * bits..(tbl + 1) * bits];
                for sig in probe_signatures(z, self.params.probes) {
                    if let Some(ids) = map.get(&sig) {
                        out.extend_from_slice(ids);
                    }
                }
            }
        }
        let _span = crate::obs::span(&crate::obs::QUERY_SCAN);
        out.sort_unstable();
        out.dedup();
        out.into_iter().map(|i| i as usize).collect()
    }
}

impl AnnIndex for SimHashIndex {
    fn name(&self) -> &'static str {
        "simhash"
    }

    fn len(&self) -> usize {
        self.n
    }

    fn top_k(&self, e: &Mat, norms: &[f64], i: usize, k: usize) -> TopK {
        debug_assert_eq!(e.rows, self.n);
        let cands = self.candidates(e.row(i));
        let scanned = cands.len().saturating_sub(cands.binary_search(&i).is_ok() as usize);
        TopK { hits: rerank_top_k(e, norms, i, k, cands), candidates: scanned }
    }

    fn mem_bytes(&self) -> usize {
        let plane_bytes = self.planes.data.len() * std::mem::size_of::<f64>();
        let id_bytes: usize = self
            .buckets
            .iter()
            .map(|m| {
                m.values().map(|v| v.len() * std::mem::size_of::<u32>()).sum::<usize>()
                    + m.len() * std::mem::size_of::<u64>()
            })
            .sum();
        plane_bytes + id_bytes
    }
}

/// `projs[r] = <planes.row(r), row>` for every hyperplane.
fn project_into(planes: &Mat, row: &[f64], projs: &mut [f64]) {
    debug_assert_eq!(projs.len(), planes.rows);
    for (r, out) in projs.iter_mut().enumerate() {
        *out = planes.row(r).iter().zip(row).map(|(a, b)| a * b).sum();
    }
}

/// Pack projection signs into a signature (bit b set ⇔ `z[b] >= 0`, so a
/// positively rescaled row — including an exactly-zero projection — maps
/// to the same signature).
fn pack_signs(z: &[f64]) -> u64 {
    let mut sig = 0u64;
    for (b, &v) in z.iter().enumerate() {
        if v >= 0.0 {
            sig |= 1u64 << b;
        }
    }
    sig
}

/// A pending probe in the query-directed enumeration: a subset of the
/// margin-sorted bit positions, represented by its flip mask, its total
/// flipped margin, and the largest sorted position it contains.
struct Probe {
    score: f64,
    mask: u64,
    max_pos: usize,
}

impl PartialEq for Probe {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.mask == other.mask
    }
}
impl Eq for Probe {}
impl PartialOrd for Probe {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Probe {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // BinaryHeap is a max-heap; invert to pop the smallest score
        // first. total_cmp keeps the order total (scores are finite).
        other
            .score
            .total_cmp(&self.score)
            .then_with(|| other.mask.cmp(&self.mask))
    }
}

/// The probe sequence for one table: the query's own signature first,
/// then signatures with low-margin bit subsets flipped, in increasing
/// total flipped margin, `probes` signatures in total.
///
/// Subsets of the margin-sorted positions are enumerated with the classic
/// shift/expand heap (Lv et al.): every non-empty subset is generated
/// exactly once, in non-decreasing score order, so `probes >= 2^bits`
/// visits every possible signature of the table.
fn probe_signatures(z: &[f64], probes: usize) -> Vec<u64> {
    let bits = z.len();
    let base = pack_signs(z);
    let total = if bits >= usize::BITS as usize - 1 {
        usize::MAX
    } else {
        1usize << bits
    };
    let want = probes.min(total);
    let mut out = Vec::with_capacity(want);
    out.push(base);
    if want == 1 {
        return out;
    }
    // Sort bit positions by |margin| ascending: flipping the cheapest
    // bits first.
    let mut order: Vec<usize> = (0..bits).collect();
    order.sort_by(|&a, &b| z[a].abs().total_cmp(&z[b].abs()).then(a.cmp(&b)));
    let margin = |pos: usize| z[order[pos]].abs();
    let flip = |pos: usize| 1u64 << order[pos];

    let mut heap: BinaryHeap<Probe> = BinaryHeap::new();
    heap.push(Probe { score: margin(0), mask: flip(0), max_pos: 0 });
    while out.len() < want {
        let Some(p) = heap.pop() else { break };
        out.push(base ^ p.mask);
        if p.max_pos + 1 < bits {
            // expand: add the next sorted position.
            heap.push(Probe {
                score: p.score + margin(p.max_pos + 1),
                mask: p.mask | flip(p.max_pos + 1),
                max_pos: p.max_pos + 1,
            });
            // shift: replace the largest position with the next one.
            heap.push(Probe {
                score: p.score - margin(p.max_pos) + margin(p.max_pos + 1),
                mask: (p.mask ^ flip(p.max_pos)) | flip(p.max_pos + 1),
                max_pos: p.max_pos + 1,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Coordinator, EmbedJob};
    use crate::embed::Params;
    use crate::funcs::SpectralFn;
    use crate::index::{evaluate_recall, row_norms, ExactIndex};
    use crate::sparse::{gen, graph};
    use crate::testing::prop::{check, forall};

    #[test]
    fn probe_sequence_is_unique_and_covers_space() {
        let z = [0.3, -0.1, 0.7, -0.4];
        let sigs = probe_signatures(&z, 1 << 4);
        assert_eq!(sigs.len(), 16);
        let mut sorted = sigs.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 16, "all 2^bits signatures, each once");
        assert_eq!(sigs[0], pack_signs(&z), "own bucket first");
        // Second probe flips exactly the lowest-margin bit (bit 1).
        assert_eq!(sigs[1], pack_signs(&z) ^ (1 << 1));
    }

    #[test]
    fn probe_scores_are_nondecreasing() {
        let z = [0.5, -0.25, 0.125, 0.8, -0.05];
        let sigs = probe_signatures(&z, 1 << 5);
        let base = pack_signs(&z);
        let score = |sig: u64| -> f64 {
            (0..5).filter(|&b| (sig ^ base) & (1 << b) != 0).map(|b| z[b].abs()).sum()
        };
        for w in sigs.windows(2).skip(1) {
            assert!(score(w[0]) <= score(w[1]) + 1e-12);
        }
    }

    #[test]
    fn full_probe_coverage_equals_exact_top_k() {
        forall(
            91,
            12,
            |r| {
                let n = 20 + r.below(40);
                (Mat::randn(r, n, 6), 1 + r.below(6))
            },
            |(e, k)| {
                let norms = row_norms(e);
                let idx = SimHashIndex::build(
                    e,
                    SimHashParams {
                        tables: 1,
                        bits: 3,
                        probes: 1 << 3,
                        seed: 5,
                        ..Default::default()
                    },
                );
                let exact = ExactIndex::new(e.rows);
                for i in 0..e.rows.min(8) {
                    let a = idx.top_k(e, &norms, i, *k);
                    let b = exact.top_k(e, &norms, i, *k);
                    check(a.hits == b.hits, format!("i={i}: {:?} != {:?}", a.hits, b.hits))?;
                    check(
                        a.candidates == e.rows - 1,
                        format!("full probing must scan all rows, got {}", a.candidates),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn signatures_invariant_to_positive_row_rescaling() {
        forall(
            92,
            16,
            |r| {
                let e = Mat::randn(r, 12, 8);
                let scales: Vec<f64> = (0..12).map(|_| r.uniform(1e-6, 1e6)).collect();
                (e, scales)
            },
            |(e, scales)| {
                let idx = SimHashIndex::build(
                    e,
                    SimHashParams { tables: 3, bits: 10, probes: 1, seed: 7, ..Default::default() },
                );
                for i in 0..e.rows {
                    let row = e.row(i);
                    let scaled: Vec<f64> = row.iter().map(|x| x * scales[i]).collect();
                    check(
                        idx.signatures(row) == idx.signatures(&scaled),
                        format!("row {i} signature changed under scale {}", scales[i]),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn recall_at_10_on_sbm_with_default_params() {
        // An SBM serving workload end-to-end: embed, index with the
        // default tables/bits/probes, and require mean recall@10 >= 0.9
        // against the exact scan.
        let mut rng = Rng::new(93);
        let g = gen::sbm_by_degree(&mut rng, 1500, 15, 12.0, 0.8);
        let na = graph::normalized_adjacency(&g.adj);
        let job = EmbedJob::new(
            Params { d: 24, order: 60, cascade: 2, ..Params::default() },
            SpectralFn::Step { c: 0.7 },
            17,
        );
        let e = Coordinator::new(2).run(&na, &job).unwrap().e;
        let norms = row_norms(&e);
        let idx = SimHashIndex::build(&e, SimHashParams::default());
        let queries: Vec<usize> = (0..100).map(|_| rng.below(e.rows)).collect();
        let rep = evaluate_recall(&e, &norms, &idx, &queries, 10);
        assert!(
            rep.mean_recall >= 0.9,
            "recall@10 = {:.3} (candidates/query = {:.1})",
            rep.mean_recall,
            rep.mean_candidates
        );
        // The point of the index: the candidate sets are small.
        assert!(
            rep.candidate_fraction < 0.5,
            "candidate fraction {:.3} not sublinear",
            rep.candidate_fraction
        );
    }

    #[test]
    fn build_is_deterministic_and_reports_memory() {
        let mut rng = Rng::new(94);
        let e = Mat::randn(&mut rng, 50, 6);
        let p = SimHashParams { tables: 2, bits: 8, probes: 4, seed: 11, ..Default::default() };
        let a = SimHashIndex::build(&e, p);
        let b = SimHashIndex::build(&e, p);
        for i in 0..e.rows {
            assert_eq!(a.signatures(e.row(i)), b.signatures(e.row(i)));
            assert_eq!(a.candidates(e.row(i)), b.candidates(e.row(i)));
        }
        assert!(a.mem_bytes() > 0);
        assert_eq!(a.name(), "simhash");
        assert_eq!(a.len(), 50);
    }

    #[test]
    fn build_is_thread_count_independent() {
        let mut rng = Rng::new(96);
        let e = Mat::randn(&mut rng, 400, 8);
        let p = SimHashParams { tables: 3, bits: 6, probes: 4, seed: 13, ..Default::default() };
        let base = SimHashIndex::build(&e, p);
        for threads in [2usize, 4] {
            let idx = SimHashIndex::build(
                &e,
                SimHashParams { exec: ExecPolicy::with_threads(threads), ..p },
            );
            for i in 0..e.rows {
                assert_eq!(base.signatures(e.row(i)), idx.signatures(e.row(i)));
                assert_eq!(
                    base.candidates(e.row(i)),
                    idx.candidates(e.row(i)),
                    "row {i} at {threads} threads"
                );
            }
            assert_eq!(base.mem_bytes(), idx.mem_bytes());
        }
    }

    #[test]
    fn every_row_is_its_own_candidate() {
        // A query row always lands in its own bucket, so with probes=1
        // the candidate set still contains the row itself.
        let mut rng = Rng::new(95);
        let e = Mat::randn(&mut rng, 30, 5);
        let idx = SimHashIndex::build(
            &e,
            SimHashParams { tables: 1, bits: 6, probes: 1, seed: 3, ..Default::default() },
        );
        for i in 0..e.rows {
            assert!(idx.candidates(e.row(i)).contains(&i));
        }
    }
}
