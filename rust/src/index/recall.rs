//! Recall@k evaluation: any [`AnnIndex`] against the exact scan.
//!
//! Recall@k is the fraction of the exact top-k a query's indexed answer
//! recovers, averaged over query vertices — the standard ANN quality
//! metric, paired here with the mean candidate-set size so the
//! recall/work trade-off is visible in one report (CLI `cse serve
//! --index`, bench group `serving`).

use super::{rerank_top_k, AnnIndex};
use crate::linalg::Mat;
use crate::util::json::Json;

/// Aggregate recall/work statistics over a query sample.
#[derive(Clone, Debug)]
pub struct RecallReport {
    pub k: usize,
    pub queries: usize,
    /// Mean over queries of |indexed ∩ exact| / |exact|.
    pub mean_recall: f64,
    /// Worst single-query recall in the sample.
    pub min_recall: f64,
    /// Mean exactly-scored candidate count per query.
    pub mean_candidates: f64,
    /// `mean_candidates / n` — fraction of rows scanned per query.
    pub candidate_fraction: f64,
}

impl RecallReport {
    /// Machine-readable form (reused by the bench JSON emitter).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("k".into(), Json::Num(self.k as f64));
        m.insert("queries".into(), Json::Num(self.queries as f64));
        m.insert("mean_recall".into(), Json::Num(self.mean_recall));
        m.insert("min_recall".into(), Json::Num(self.min_recall));
        m.insert("mean_candidates".into(), Json::Num(self.mean_candidates));
        m.insert("candidate_fraction".into(), Json::Num(self.candidate_fraction));
        Json::Obj(m)
    }
}

/// Evaluate `index` on `queries` (vertex ids) at cutoff `k`, comparing
/// against a fresh exact scan per query. Empty `queries` yields NaN
/// recalls and zero counts.
pub fn evaluate_recall(
    e: &Mat,
    norms: &[f64],
    index: &dyn AnnIndex,
    queries: &[usize],
    k: usize,
) -> RecallReport {
    assert_eq!(index.len(), e.rows, "index built over a different embedding");
    let mut recalls = Vec::with_capacity(queries.len());
    let mut cand_total = 0usize;
    for &i in queries {
        let exact = rerank_top_k(e, norms, i, k, 0..e.rows);
        let got = index.top_k(e, norms, i, k);
        cand_total += got.candidates;
        if exact.is_empty() {
            recalls.push(1.0);
            continue;
        }
        let hit = got
            .hits
            .iter()
            .filter(|(j, _)| exact.iter().any(|(ej, _)| ej == j))
            .count();
        recalls.push(hit as f64 / exact.len() as f64);
    }
    let mean_candidates = if queries.is_empty() {
        0.0
    } else {
        cand_total as f64 / queries.len() as f64
    };
    RecallReport {
        k,
        queries: queries.len(),
        mean_recall: crate::util::stats::mean(&recalls),
        min_recall: recalls.iter().cloned().fold(f64::NAN, f64::min),
        mean_candidates,
        candidate_fraction: if e.rows == 0 { 0.0 } else { mean_candidates / e.rows as f64 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::{row_norms, ExactIndex, SimHashIndex, SimHashParams};
    use crate::util::rng::Rng;

    #[test]
    fn exact_index_has_unit_recall() {
        let mut rng = Rng::new(101);
        let e = Mat::randn(&mut rng, 80, 6);
        let norms = row_norms(&e);
        let idx = ExactIndex::new(80);
        let queries: Vec<usize> = (0..20).collect();
        let rep = evaluate_recall(&e, &norms, &idx, &queries, 5);
        assert_eq!(rep.mean_recall, 1.0);
        assert_eq!(rep.min_recall, 1.0);
        assert_eq!(rep.queries, 20);
        assert!((rep.mean_candidates - 79.0).abs() < 1e-12);
        assert!((rep.candidate_fraction - 79.0 / 80.0).abs() < 1e-12);
    }

    #[test]
    fn full_probe_simhash_has_unit_recall() {
        let mut rng = Rng::new(102);
        let e = Mat::randn(&mut rng, 50, 5);
        let norms = row_norms(&e);
        let idx = SimHashIndex::build(
            &e,
            SimHashParams { tables: 2, bits: 4, probes: 1 << 4, seed: 9, ..Default::default() },
        );
        let queries: Vec<usize> = (0..50).step_by(5).collect();
        let rep = evaluate_recall(&e, &norms, &idx, &queries, 8);
        assert_eq!(rep.mean_recall, 1.0, "{rep:?}");
    }

    #[test]
    fn report_serializes_to_json() {
        let rep = RecallReport {
            k: 10,
            queries: 4,
            mean_recall: 0.95,
            min_recall: 0.8,
            mean_candidates: 123.5,
            candidate_fraction: 0.01235,
        };
        let j = rep.to_json();
        assert_eq!(j.get("k").and_then(|v| v.as_usize()), Some(10));
        assert_eq!(j.get("mean_recall").and_then(|v| v.as_f64()), Some(0.95));
        let roundtrip = Json::parse(&j.to_string()).unwrap();
        assert_eq!(roundtrip.get("queries").and_then(|v| v.as_usize()), Some(4));
    }
}
