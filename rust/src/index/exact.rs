//! The exact-scan baseline behind the [`AnnIndex`] trait.
//!
//! This is the previous `SimilarityService::top_k` behaviour — an
//! `O(n·d)` linear scan with a bounded best-k buffer — expressed as an
//! index so the service can route every `Query::TopK` through one code
//! path and so the recall harness has a trivially-correct reference.

use super::{rerank_top_k, AnnIndex, TopK};
use crate::linalg::Mat;

/// Exact linear-scan "index": no acceleration structure, 100% recall.
pub struct ExactIndex {
    n: usize,
}

impl ExactIndex {
    pub fn new(n: usize) -> Self {
        ExactIndex { n }
    }
}

impl AnnIndex for ExactIndex {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn len(&self) -> usize {
        self.n
    }

    fn top_k(&self, e: &Mat, norms: &[f64], i: usize, k: usize) -> TopK {
        debug_assert_eq!(e.rows, self.n);
        TopK {
            hits: rerank_top_k(e, norms, i, k, 0..self.n),
            candidates: self.n.saturating_sub(1),
        }
    }

    fn mem_bytes(&self) -> usize {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::row_norms;
    use crate::util::rng::Rng;

    #[test]
    fn exact_index_matches_direct_rerank() {
        let mut rng = Rng::new(81);
        let e = Mat::randn(&mut rng, 40, 6);
        let norms = row_norms(&e);
        let idx = ExactIndex::new(40);
        for &i in &[0, 17, 39] {
            let got = idx.top_k(&e, &norms, i, 7);
            assert_eq!(got.hits, rerank_top_k(&e, &norms, i, 7, 0..40));
            assert_eq!(got.candidates, 39);
        }
        assert_eq!(idx.name(), "exact");
        assert_eq!(idx.len(), 40);
        assert_eq!(idx.mem_bytes(), 0);
    }
}
