//! Benchmark harness regenerating every figure and table of the paper's
//! evaluation (§5) plus the ablations and §Perf measurements indexed in
//! DESIGN.md §4. criterion is unavailable offline; this is a custom
//! `harness = false` binary.
//!
//! Usage:
//!   cargo bench                      # everything (scaled-down defaults)
//!   cargo bench -- fig1a             # one experiment
//!   cargo bench -- fig1a fig1b       # several
//!   CSE_BENCH_N=8000 cargo bench -- runtime   # bigger workload
//!
//! Experiments: fig1a fig1b runtime clustering ablation_poly ablation_L
//!              ablation_jl perf serving kernels
//!
//! Each experiment prints a paper-style table AND writes a TSV under
//! bench_out/ for external plotting.

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use cse::cluster::{kmeans, modularity, KmeansParams};
use cse::coordinator::service::Query;
use cse::coordinator::{measure_serving, Coordinator, EmbedJob, ServingSample, SimilarityService};
use cse::eigen::lanczos::{lanczos, LanczosParams};
use cse::eigen::nystrom::nystrom;
use cse::eigen::rsvd::{rsvd, RsvdParams};
use cse::eigen::simult::simultaneous_iteration;
use cse::embed::op::Operator;
use cse::embed::{FastEmbed, Params};
use cse::funcs::SpectralFn;
use cse::index::{evaluate_recall, AnnIndex, RecallReport, SimHashIndex, SimHashParams};
use cse::linalg::Mat;
use cse::par::ExecPolicy;
use cse::poly::{cascade, chebyshev, legendre, Basis};
use cse::sparse::{gen, graph, io, tune, Csr, SellCs};
use cse::util::json::Json;
use cse::util::rng::Rng;
use cse::util::stats;
use cse::util::timer::Timer;

/// Allocation-counting wrapper around the system allocator, so the
/// `kernels` experiment can report allocs/iteration of the hot loops
/// (the zero-steady-state-allocation acceptance check) without any
/// external profiler.
struct CountingAlloc;

static ALLOC_COUNT: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs_now() -> usize {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with("--")).collect();
    let all = [
        "fig1a", "fig1b", "runtime", "clustering", "ablation_poly", "ablation_L", "ablation_jl",
        "perf", "serving", "kernels",
    ];
    let selected: Vec<&str> = if args.is_empty() {
        all.to_vec()
    } else {
        all.iter().copied().filter(|name| args.iter().any(|a| name.starts_with(a.as_str()))).collect()
    };
    if selected.is_empty() {
        eprintln!("no experiment matches {args:?}; available: {all:?}");
        std::process::exit(2);
    }
    // Pool workers pin to node-local core sets at spawn when the build
    // supports it (`--features affinity` on Linux); recorded as the
    // `pin` flag in the bench JSON entries. Bitwise-invisible.
    cse::par::affinity::set_pinning(cse::par::affinity::can_pin());
    std::fs::create_dir_all("bench_out").ok();
    for name in selected {
        println!("\n=============================================================");
        println!("== {name}");
        println!("=============================================================");
        match name {
            "fig1a" => fig1a(),
            "fig1b" => fig1b(),
            "runtime" => runtime_table(),
            "clustering" => clustering_table(),
            "ablation_poly" => ablation_poly(),
            "ablation_L" => ablation_order(),
            "ablation_jl" => ablation_jl(),
            "perf" => perf(),
            "serving" => serving(),
            "kernels" => kernels(),
            _ => unreachable!(),
        }
    }
}

fn bench_n(default: usize) -> usize {
    std::env::var("CSE_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Baseline snapshot of every obs stage histogram, for delta breakdowns.
fn stage_baseline() -> Vec<cse::obs::HistSnapshot> {
    cse::obs::STAGES.iter().map(|s| s.hist.snapshot()).collect()
}

/// Per-stage latency breakdown since `base` (stages with no new records
/// are omitted), as a JSON object keyed by stage name. Percentiles are
/// exact on the histograms' log-bucket grid.
fn stage_delta_json(base: &[cse::obs::HistSnapshot]) -> Json {
    let mut m = std::collections::BTreeMap::new();
    for (stage, before) in cse::obs::STAGES.iter().zip(base) {
        let d = stage.hist.snapshot().sub(before);
        if d.count == 0 {
            continue;
        }
        let mut s = std::collections::BTreeMap::new();
        s.insert("count".to_string(), Json::Num(d.count as f64));
        s.insert("total_ms".to_string(), Json::Num(d.sum as f64 / 1e6));
        s.insert("mean_us".to_string(), Json::Num(d.mean() / 1e3));
        s.insert("p50_us".to_string(), Json::Num(d.percentile(50.0) as f64 / 1e3));
        s.insert("p99_us".to_string(), Json::Num(d.percentile(99.0) as f64 / 1e3));
        m.insert(stage.name.to_string(), Json::Obj(s));
    }
    Json::Obj(m)
}

/// The DBLP-analog workload + exact reference embedding (DESIGN.md §3).
struct DblpAnalog {
    na: Csr,
    /// Exact spectral embedding E = [v_1 .. v_k] for f = I(lambda >= c).
    e_exact: Mat,
    /// Threshold used (just below lambda_k).
    c: f64,
}

fn dblp_analog_deg(n: usize, k: usize, deg_in: f64, deg_out: f64, rng: &mut Rng) -> DblpAnalog {
    let g = gen::sbm_by_degree(rng, n, k, deg_in, deg_out);
    let na = graph::normalized_adjacency(&g.adj);
    // Exact reference: k leading eigenvectors. The k community
    // eigenvalues are nearly degenerate, which single-vector Krylov
    // resolves only through rounding noise; a block method (simultaneous
    // iteration) captures the whole subspace natively — and for the
    // reference *embedding* any orthonormal basis of that subspace gives
    // the same pairwise geometry.
    let pe = simultaneous_iteration(&na, k, 100, rng, &ExecPolicy::serial());
    let c = pe.values[k - 1] - 1e-4;
    let e_exact = pe.vectors.clone();
    DblpAnalog { na, e_exact, c }
}

fn dblp_analog(n: usize, k: usize, rng: &mut Rng) -> DblpAnalog {
    dblp_analog_deg(n, k, 12.0, 0.8, rng)
}

fn sample_pair_devs(
    exact: &Mat,
    approx: &Mat,
    pairs: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    let n = exact.rows;
    let mut devs = Vec::with_capacity(pairs);
    while devs.len() < pairs {
        let i = rng.below(n);
        let j = rng.below(n);
        if i == j {
            continue;
        }
        devs.push(approx.row_corr(i, j) - exact.row_corr(i, j));
    }
    devs
}

// ---------------------------------------------------------------- Fig 1a

/// Figure 1a: percentiles of (compressive − exact) normalized correlation
/// vs the number of random projections d.
fn fig1a() {
    let n = bench_n(4000);
    let k = 40;
    let order = 180;
    let mut rng = Rng::new(1);
    println!("DBLP-analog: n={n}, exact reference = {k} leading eigenvectors");
    let w = dblp_analog(n, k, &mut rng);
    println!("threshold c = {:.4} (lambda_{k})", w.c);

    let ds = [1usize, 5, 10, 20, 40, 60, 80, 120];
    let ps = [1.0, 5.0, 25.0, 50.0, 75.0, 95.0, 99.0];
    let mut tsv: Vec<Vec<f64>> = Vec::new();
    println!(
        "\n{:>4} | {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7}",
        "d", "p1", "p5", "p25", "p50", "p75", "p95", "p99"
    );
    for &d in &ds {
        let fe = FastEmbed::new(Params { d, order, cascade: 2, ..Params::default() });
        let mut rng_e = Rng::new(100 + d as u64);
        let emb = fe.embed(&w.na, &SpectralFn::Step { c: w.c }, &mut rng_e);
        let mut devs = sample_pair_devs(&w.e_exact, &emb.e, 20_000, &mut rng_e);
        let row = stats::percentiles(&mut devs, &ps);
        println!(
            "{:>4} | {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>7.3} {:>7.3}",
            d, row[0], row[1], row[2], row[3], row[4], row[5], row[6]
        );
        let mut line = vec![d as f64];
        line.extend(row);
        tsv.push(line);
    }
    io::write_tsv(
        Path::new("bench_out/fig1a.tsv"),
        &["d", "p1", "p5", "p25", "p50", "p75", "p95", "p99"],
        &tsv,
    )
    .unwrap();
    println!("\npaper shape: spread shrinks ~1/sqrt(d), saturates at poly-approx error");
    println!("paper claim @ d=80: 90% of pairs within +-0.2   -> wrote bench_out/fig1a.tsv");
}

// ---------------------------------------------------------------- Fig 1b

/// Figure 1b: percentile curves of compressive correlation vs exact
/// correlation, cascade b=1 (biased) vs b=2 (unbiased).
fn fig1b() {
    let n = bench_n(4000);
    let k = 40;
    // Modest order: the b=1 bias (Fig 1b left) comes from bulk-eigenvalue
    // leakage, which a very high order would suppress even without
    // cascading at this reduced n. L=60 ~ the paper's L/n ratio.
    let order = 60;
    let d = 80;
    let mut rng = Rng::new(2);
    // Marginal community/bulk gap: bulk eigenvalues sit just below the
    // threshold, so unsharpened nulls (b=1) leak — the regime Fig 1b
    // demonstrates. (The strong-gap fig1a graph would hide the effect.)
    let w = dblp_analog_deg(n, k, 5.0, 1.6, &mut rng);
    println!("n={n}, d={d}, L={order}, threshold c={:.4}", w.c);

    let mut tsv: Vec<Vec<f64>> = Vec::new();
    for &b in &[1usize, 2] {
        let fe = FastEmbed::new(Params { d, order, cascade: b, ..Params::default() });
        let mut rng_e = Rng::new(200);
        let emb = fe.embed(&w.na, &SpectralFn::Step { c: w.c }, &mut rng_e);
        // Bin pairs by exact correlation, report percentiles of
        // compressive correlation per bin.
        let mut binner = stats::Binner::new(-0.25, 1.0, 10);
        for _ in 0..60_000 {
            let i = rng_e.below(n);
            let j = rng_e.below(n);
            if i == j {
                continue;
            }
            binner.add(w.e_exact.row_corr(i, j), emb.e.row_corr(i, j));
        }
        println!("\n-- cascade b = {b} --");
        println!("{:>10} | {:>7} {:>7} {:>7} {:>6}", "exact-corr", "p5", "p50", "p95", "count");
        let centers: Vec<f64> = (0..10).map(|t| binner.bin_center(t)).collect();
        for (bin, &center) in centers.iter().enumerate() {
            let vals = &mut binner.bins_mut()[bin];
            if vals.len() < 10 {
                continue;
            }
            let row = stats::percentiles(vals, &[5.0, 50.0, 95.0]);
            println!(
                "{:>10.2} | {:>7.3} {:>7.3} {:>7.3} {:>6}",
                center,
                row[0],
                row[1],
                row[2],
                vals.len()
            );
            tsv.push(vec![b as f64, center, row[0], row[1], row[2], vals.len() as f64]);
        }
    }
    io::write_tsv(
        Path::new("bench_out/fig1b.tsv"),
        &["b", "exact_corr", "p5", "p50", "p95", "count"],
        &tsv,
    )
    .unwrap();
    println!("\npaper shape: b=1 median curve biased off y=x; bias disappears at b=2");
    println!("-> wrote bench_out/fig1b.tsv");
}

// ------------------------------------------------------------- runtime T1

/// §5 runtime claims: FastEmbed vs exact partial eigendecomposition vs
/// the other solvers, same operator, same machine.
fn runtime_table() {
    let n = bench_n(6000);
    let k = 150; // eigenvectors the embedding must capture
    let d = 64;
    let order = 180;
    let mut rng = Rng::new(3);
    let g = gen::sbm_by_degree(&mut rng, n, k / 2, 12.0, 0.8);
    let na = graph::normalized_adjacency(&g.adj);
    println!("n={n} nnz={} | capture k={k} eigenvectors, d={d}, L={order}", na.nnz());

    // Threshold from a probe Lanczos (not charged to FastEmbed: the
    // paper treats c as given; we still report it).
    let t = Timer::start();
    let probe = lanczos(&na, k, &LanczosParams { subspace: Some(4 * k), ..Default::default() }, &mut rng);
    let t_probe = t.elapsed_secs();
    let c = probe.values[k - 1] - 1e-4;

    let mut rows: Vec<(String, f64, usize)> = Vec::new();

    let t = Timer::start();
    let fe = FastEmbed::new(Params { d, order, cascade: 2, ..Params::default() });
    let emb = fe.embed(&na, &SpectralFn::Step { c }, &mut rng);
    rows.push(("FastEmbed (ours)".into(), t.elapsed_secs(), emb.matvecs));

    let t = Timer::start();
    // 4k subspace = what it actually takes to resolve the near-degenerate
    // community cluster (matching what ARPACK restarts achieve).
    let pe = lanczos(&na, k, &LanczosParams { subspace: Some(4 * k), ..Default::default() }, &mut rng);
    rows.push((format!("Lanczos full-reorth (k={k})"), t.elapsed_secs(), pe.matvecs));

    let t = Timer::start();
    let si = simultaneous_iteration(&na, k, 40, &mut rng, &ExecPolicy::serial());
    rows.push((format!("simultaneous iteration (k={k})"), t.elapsed_secs(), si.matvecs));

    let t = Timer::start();
    let rs = rsvd(&na, k, &RsvdParams::default(), &mut rng);
    rows.push((format!("randomized SVD (k={k}, q=5, l=10)"), t.elapsed_secs(), rs.matvecs));

    let t = Timer::start();
    let ny = nystrom(&na, k, (4 * k).min(n), &mut rng);
    rows.push((format!("Nystrom (k={k}, s={})", (4 * k).min(n)), t.elapsed_secs(), ny.matvecs));

    let fe_time = rows[0].1;
    println!("\n{:<38} {:>9} {:>12} {:>9}", "method", "time", "col-matvecs", "vs ours");
    let mut tsv = Vec::new();
    for (i, (name, secs, mv)) in rows.iter().enumerate() {
        println!("{name:<38} {secs:>8.2}s {mv:>12} {:>8.1}x", secs / fe_time);
        tsv.push(vec![i as f64, *secs, *mv as f64]);
    }
    println!("(threshold probe, not charged: {t_probe:.2}s)");
    io::write_tsv(Path::new("bench_out/runtime.tsv"), &["row", "secs", "matvecs"], &tsv).unwrap();
    println!(
        "\npaper claim: ~2 orders of magnitude vs exact at n=317k/k=500; at this\n\
         reduced scale expect >=5x vs Lanczos, growing with n and k \
         -> wrote bench_out/runtime.tsv"
    );
}

// ----------------------------------------------------------- clustering T2

/// §5 Amazon clustering table: K-means modularity across embeddings.
fn clustering_table() {
    let n = bench_n(4000);
    let communities = 50;
    // d < keep: the compressive embedding packs `keep` eigenvectors into
    // fewer K-means dimensions than the exact baseline can (the paper's
    // 500-eigs-in-80-dims argument).
    let d = 32;
    let keep = communities;
    let restarts = 9;
    let mut rng = Rng::new(4);
    // Heterogeneous community strengths: structural eigenvalues spread
    // over a band, so exact-d truncation drops the weak communities —
    // the regime the paper's Amazon experiment lives in.
    let g = gen::sbm_hetero(&mut rng, n, communities, 5.0, 18.0, 0.6);
    let na = graph::normalized_adjacency(&g.adj);
    println!("Amazon-analog: n={n} communities={communities} nnz={}", na.nnz());

    // Block method: the `keep` community eigenvalues are near-degenerate.
    let probe = simultaneous_iteration(&na, keep + 8, 100, &mut rng, &ExecPolicy::serial());
    let c = probe.values[keep - 1] - 1e-3;

    let med_mod = |e: &Mat, seed: u64| -> f64 {
        let mut r = Rng::new(seed);
        let mods: Vec<f64> = (0..restarts)
            .map(|_| {
                let p = KmeansParams {
                    k: communities,
                    max_iters: 25,
                    tol: 1e-5,
                    ..Default::default()
                };
                let km = kmeans(e, &p, &mut r);
                modularity(&g.adj, &km.assignment)
            })
            .collect();
        stats::median(&mods)
    };

    let mut tsv = Vec::new();
    println!("\n{:<44} {:>9} {:>11}", "embedding", "time", "modularity");
    let mut report = |name: &str, secs: f64, q: f64, idx: usize| {
        println!("{name:<44} {secs:>8.2}s {q:>11.4}");
        tsv.push(vec![idx as f64, secs, q]);
    };

    let t = Timer::start();
    let job = EmbedJob::new(
        Params { d, order: 160, cascade: 2, ..Params::default() },
        SpectralFn::Step { c },
        11,
    );
    let res = Coordinator::new(1).run(&na, &job).unwrap();
    let t_fe = t.elapsed_secs();
    report(&format!("FastEmbed d={d} capturing {keep} eigs"), t_fe, med_mod(&res.e, 21), 0);

    let t = Timer::start();
    let e80 = simultaneous_iteration(&na, d, 100, &mut rng, &ExecPolicy::serial());
    report(&format!("exact {d} eigenvectors"), t.elapsed_secs(), med_mod(&e80.vectors, 22), 1);

    let t = Timer::start();
    let e120 = simultaneous_iteration(&na, 3 * d / 2, 100, &mut rng, &ExecPolicy::serial());
    report(
        &format!("exact {} eigenvectors (K-means on {})", 3 * d / 2, 3 * d / 2),
        t.elapsed_secs(),
        med_mod(&e120.vectors, 23),
        2,
    );

    let t = Timer::start();
    let rs = rsvd(&na, d, &RsvdParams::default(), &mut rng);
    report(&format!("randomized SVD {d} (q=5, l=10)"), t.elapsed_secs(), med_mod(&rs.vectors, 24), 3);

    io::write_tsv(Path::new("bench_out/clustering.tsv"), &["row", "secs", "modularity"], &tsv).unwrap();
    println!(
        "\npaper: 0.87 (ours) > 0.845 (exact 120) > 0.835 (exact 80) > 0.748 (RSVD)\n\
         expected shape: FastEmbed top or tied-top, RSVD worst -> wrote bench_out/clustering.tsv"
    );
}

// ------------------------------------------------------------- ablation A1

/// A1: Legendre vs Chebyshev (vs Jackson-damped Chebyshev) fitting error
/// delta(L) for the two weighing-function families the paper uses.
fn ablation_poly() {
    let orders = [10usize, 20, 40, 80, 160, 320];
    println!("delta = max|f - f~_L| on [-1,1] (Theorem 1's additive distortion)\n");
    let mut tsv = Vec::new();
    for (fname, f) in [
        ("step c=0.7", SpectralFn::Step { c: 0.7 }),
        ("commute-time", SpectralFn::CommuteTime { c: -1.0, eps: 0.05 }),
    ] {
        println!("-- f = {fname} --");
        println!("{:>5} {:>12} {:>12} {:>14}", "L", "legendre", "chebyshev", "cheb+jackson");
        for &ll in &orders {
            let leg = cascade::plan(&f, ll, 1, Basis::Legendre).stage;
            let che = cascade::plan(&f, ll, 1, Basis::Chebyshev).stage;
            let dam = chebyshev::damped(&che, &chebyshev::jackson_damping(che.order()));
            let fe = |x: f64| f.eval(x);
            // Measure off the discontinuity (+-0.02) where distortion is
            // actionable; at the jump delta ~ 0.5 for any polynomial.
            let grid_err = |s: &cse::poly::Series| {
                (0..2001)
                    .map(|i| -1.0 + i as f64 / 1000.0)
                    .filter(|x| match f {
                        SpectralFn::Step { c } => (x - c).abs() > 0.02,
                        // measure away from the eps-clamp kink at 1-eps
                        SpectralFn::CommuteTime { eps, .. } => (x - (1.0 - eps)).abs() > 0.02,
                        _ => true,
                    })
                    .map(|x| (fe(x) - s.eval(x)).abs())
                    .fold(0.0, f64::max)
            };
            let (e1, e2, e3) = (grid_err(&leg), grid_err(&che), grid_err(&dam));
            println!("{ll:>5} {e1:>12.4e} {e2:>12.4e} {e3:>14.4e}");
            tsv.push(vec![ll as f64, e1, e2, e3]);
        }
        println!();
    }
    io::write_tsv(
        Path::new("bench_out/ablation_poly.tsv"),
        &["L", "legendre", "chebyshev", "cheb_jackson"],
        &tsv,
    )
    .unwrap();
    println!("shape: chebyshev converges faster off the jump (paper §4's remark); \
              jackson kills Gibbs ringing -> wrote bench_out/ablation_poly.tsv");
}

// ------------------------------------------------------------- ablation A2

/// A2: embedding accuracy vs polynomial order L at fixed d.
fn ablation_order() {
    let n = bench_n(3000);
    let k = 30;
    let d = 64;
    let mut rng = Rng::new(5);
    let w = dblp_analog(n, k, &mut rng);
    println!("n={n} d={d} threshold c={:.4}\n", w.c);
    println!("{:>5} | {:>8} {:>8} {:>8}", "L", "p50", "p95", "time(s)");
    let mut tsv = Vec::new();
    for &order in &[20usize, 40, 80, 160, 320] {
        let fe = FastEmbed::new(Params { d, order, cascade: 2, ..Params::default() });
        let mut rng_e = Rng::new(300);
        let t = Timer::start();
        let emb = fe.embed(&w.na, &SpectralFn::Step { c: w.c }, &mut rng_e);
        let secs = t.elapsed_secs();
        let mut devs = sample_pair_devs(&w.e_exact, &emb.e, 10_000, &mut rng_e);
        devs.iter_mut().for_each(|v| *v = v.abs());
        let row = stats::percentiles(&mut devs, &[50.0, 95.0]);
        println!("{order:>5} | {:>8.4} {:>8.4} {secs:>8.2}", row[0], row[1]);
        tsv.push(vec![order as f64, row[0], row[1], secs]);
    }
    io::write_tsv(Path::new("bench_out/ablation_L.tsv"), &["L", "p50", "p95", "secs"], &tsv).unwrap();
    println!("\nshape: deviation falls with L then saturates at the JL floor for this d; \
              time grows linearly in L -> wrote bench_out/ablation_L.tsv");
}

// ------------------------------------------------------------- ablation A3

/// A3: empirical JL concentration vs the §3.1 bound.
fn ablation_jl() {
    let n = 2000;
    let points = 150;
    let mut rng = Rng::new(6);
    let x = Mat::randn(&mut rng, points, n);
    println!("{points} random points in R^{n}; measured max pairwise distortion vs d\n");
    println!("{:>5} | {:>10} {:>16}", "d", "max |eps|", "bound eps(d,beta=1)");
    let mut tsv = Vec::new();
    for &d in &[8usize, 16, 32, 64, 128, 256] {
        let om = cse::embed::omega::rademacher_omega(&mut rng, n, d);
        let proj = x.matmul(&om);
        let mut worst: f64 = 0.0;
        for i in 0..points {
            for j in 0..i {
                let orig = x.row_dist(i, &x, j);
                let emb = proj.row_dist(i, &proj, j);
                worst = worst.max((emb * emb / (orig * orig) - 1.0).abs());
            }
        }
        // Invert the bound d > (4+2b) ln n' / (e^2/2 - e^3/3) for eps.
        let mut eps_bound = 1.0f64;
        for e in (1..200).map(|t| t as f64 * 0.005) {
            if (6.0 * (points as f64).ln()) / (e * e / 2.0 - e * e * e / 3.0) <= d as f64 {
                eps_bound = e;
                break;
            }
        }
        println!("{d:>5} | {worst:>10.4} {eps_bound:>16.4}");
        tsv.push(vec![d as f64, worst, eps_bound]);
    }
    io::write_tsv(Path::new("bench_out/ablation_jl.tsv"), &["d", "measured", "bound"], &tsv).unwrap();
    println!("\nshape: measured distortion ~ O(sqrt(log n'/d)), comfortably inside the bound\n\
              -> wrote bench_out/ablation_jl.tsv");
}

// ------------------------------------------------------------- serving T3

/// One measured serving configuration (rows of the table/TSV/JSON).
struct ServingRow {
    n: usize,
    mode: &'static str,
    sample: ServingSample,
    /// Recall report vs the exact scan (None for the exact mode itself).
    recall: Option<RecallReport>,
    build_secs: f64,
}

impl ServingRow {
    fn recall_at_k(&self) -> f64 {
        self.recall.as_ref().map_or(1.0, |r| r.mean_recall)
    }
}

/// Serving throughput: exact linear scan vs the SimHash ANN index, same
/// embedding, same top-k workload, n ∈ {10k, 100k}. Reports QPS (serial
/// and batched), histogram-backed p50/p99 latency, candidate-set sizes
/// and recall@10, and appends a trajectory entry to BENCH_serving.json —
/// including a per-stage breakdown from the obs layer — so future PRs
/// can track the QPS trend. (The legacy `mean_us` field is gone after
/// its one bridging release; old entries that carry it still parse.)
fn serving() {
    let topk = 10;
    let workers = 4;
    let ns = [10_000usize, bench_n(100_000)];
    // Stage histograms on for the whole group: per-query spans cost
    // ~100 ns against queries that take tens of µs, and in exchange the
    // JSON gets true hash/probe/scan/re-rank percentiles of the exact
    // workload being measured.
    cse::obs::set_stats(true);
    let stage_base = stage_baseline();
    let mut rows: Vec<ServingRow> = Vec::new();
    for &n in &ns {
        let mut rng = Rng::new(31);
        let g = gen::sbm_by_degree(&mut rng, n, (n / 200).max(2), 8.0, 0.8);
        let na = graph::normalized_adjacency(&g.adj);
        let t = Timer::start();
        let job = EmbedJob::new(
            Params { d: 64, order: 60, cascade: 2, ..Params::default() },
            SpectralFn::Step { c: 0.75 },
            5,
        );
        let res = Coordinator::new(workers).run(&na, &job).unwrap();
        println!("\nn={n}: embedded d={} in {:.1}s ({} matvecs)", res.e.cols, t.elapsed_secs(), res.matvecs);
        let mut service = SimilarityService::new(res.e);

        // Fewer exact queries at large n — the scan is the slow thing
        // this bench exists to show.
        let nq_exact = if n > 20_000 { 100 } else { 400 };
        let nq_ann = 2_000;
        let sample: Vec<usize> = (0..100).map(|_| rng.below(n)).collect();

        let queries = |count: usize, rng: &mut Rng| -> Vec<Query> {
            (0..count).map(|_| Query::TopK { i: rng.below(n), k: topk }).collect()
        };

        let qs = queries(nq_exact, &mut rng);
        rows.push(ServingRow {
            n,
            mode: "exact",
            sample: measure_serving(&service, &qs, workers),
            recall: None,
            build_secs: 0.0,
        });

        let p = SimHashParams::default();
        let idx = SimHashIndex::build(service.embedding(), p);
        let build_secs = idx.build_secs;
        println!(
            "simhash build: tables={} bits={} probes={} in {build_secs:.2}s ({} bytes aux)",
            p.tables,
            p.bits,
            p.probes,
            idx.mem_bytes()
        );
        let rep = evaluate_recall(service.embedding(), service.norms(), &idx, &sample, topk);
        service.attach_index(Box::new(idx));
        let qs = queries(nq_ann, &mut rng);
        rows.push(ServingRow {
            n,
            mode: "simhash",
            sample: measure_serving(&service, &qs, workers),
            recall: Some(rep),
            build_secs,
        });
    }

    println!(
        "\n{:>7} {:<8} {:>10} {:>10} {:>9} {:>9} {:>10} {:>9}",
        "n", "mode", "qps(1)", "qps(4)", "p50", "p99", "cands", "recall@10"
    );
    let mut tsv = Vec::new();
    for r in &rows {
        let s = &r.sample;
        println!(
            "{:>7} {:<8} {:>10.0} {:>10.0} {:>7.0}µs {:>7.0}µs {:>10.1} {:>9.3}",
            r.n, r.mode, s.qps_serial, s.qps_batch, s.p50_us, s.p99_us, s.mean_candidates,
            r.recall_at_k()
        );
        tsv.push(vec![
            r.n as f64,
            if r.mode == "exact" { 0.0 } else { 1.0 },
            s.qps_serial,
            s.qps_batch,
            s.p50_us,
            s.p99_us,
            s.mean_candidates,
            r.recall_at_k(),
            r.build_secs,
        ]);
    }
    io::write_tsv(
        Path::new("bench_out/serving.tsv"),
        &["n", "indexed", "qps_1", "qps_batch", "p50_us", "p99_us", "candidates", "recall", "build_secs"],
        &tsv,
    )
    .unwrap();

    // Machine-readable trajectory for future PRs.
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let s = &r.sample;
            let mut m = std::collections::BTreeMap::new();
            m.insert("n".to_string(), Json::Num(r.n as f64));
            m.insert("mode".to_string(), Json::Str(r.mode.to_string()));
            m.insert("topk".to_string(), Json::Num(topk as f64));
            m.insert("qps_serial".to_string(), Json::Num(s.qps_serial));
            m.insert("qps_batch".to_string(), Json::Num(s.qps_batch));
            m.insert("p50_us".to_string(), Json::Num(s.p50_us));
            m.insert("p99_us".to_string(), Json::Num(s.p99_us));
            m.insert("mean_candidates".to_string(), Json::Num(s.mean_candidates));
            m.insert("build_secs".to_string(), Json::Num(r.build_secs));
            if let Some(rep) = &r.recall {
                m.insert("recall".to_string(), rep.to_json());
            }
            Json::Obj(m)
        })
        .collect();
    let mut entry = std::collections::BTreeMap::new();
    entry.insert("workers".to_string(), Json::Num(workers as f64));
    entry.insert("results".to_string(), Json::Arr(json_rows));
    entry.insert("stages".to_string(), stage_delta_json(&stage_base));
    let topo = cse::par::topo::detect();
    let mut topology = std::collections::BTreeMap::new();
    topology.insert("nodes".to_string(), Json::Num(topo.num_nodes() as f64));
    topology.insert("physical_cores".to_string(), Json::Num(topo.physical_cores() as f64));
    topology.insert("smt".to_string(), Json::Bool(topo.smt()));
    entry.insert("topology".to_string(), Json::Obj(topology));
    entry.insert("pin".to_string(), Json::Bool(cse::par::affinity::pinning_enabled()));
    cse::obs::set_stats(false);
    // Preserve prior runs as a trajectory; a legacy single-run file (and
    // old entries still carrying `mean_us`) contribute as-is.
    let prior = std::fs::read_to_string("BENCH_serving.json")
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let mut trajectory: Vec<Json> = match &prior {
        Some(j) => match j.get("trajectory").and_then(|t| t.as_arr()) {
            Some(entries) => entries.to_vec(),
            None if j.get("results").is_some() => vec![j.clone()],
            None => Vec::new(),
        },
        None => Vec::new(),
    };
    trajectory.push(Json::Obj(entry));
    let mut top = std::collections::BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("serving".to_string()));
    top.insert(
        "note".to_string(),
        Json::Str(
            "appended per `cargo bench -- serving` run; keep qps_batch monotone across perf PRs"
                .to_string(),
        ),
    );
    top.insert("trajectory".to_string(), Json::Arr(trajectory));
    std::fs::write("BENCH_serving.json", Json::Obj(top).to_string()).unwrap();

    for &n in &ns {
        let exact = rows.iter().find(|r| r.n == n && r.mode == "exact").unwrap();
        let ann = rows.iter().find(|r| r.n == n && r.mode == "simhash").unwrap();
        println!(
            "n={n}: simhash {:.1}x serial qps over exact, recall@10 {:.3}, scans {:.2}% of rows",
            ann.sample.qps_serial / exact.sample.qps_serial,
            ann.recall_at_k(),
            100.0 * ann.sample.mean_candidates / n as f64
        );
    }
    println!("expected shape: >=5x qps at n=1e5 with recall >=0.9 and <10% of rows scanned");
    println!("-> wrote bench_out/serving.tsv and BENCH_serving.json");
}

// -------------------------------------------------------------- kernels K1

/// The PR 2 spawn-per-region dispatcher, verbatim: `threads − 1` scoped
/// threads spawned and joined per region. Kept here as the baseline the
/// persistent pool must beat on small regions.
fn scoped_run_indexed(threads: usize, tasks: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.clamp(1, tasks.max(1));
    if threads <= 1 {
        for k in 0..tasks {
            f(k);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let worker = || loop {
        let k = next.fetch_add(1, Ordering::Relaxed);
        if k >= tasks {
            break;
        }
        f(k);
    };
    let worker = &worker;
    std::thread::scope(|scope| {
        for _ in 1..threads {
            scope.spawn(worker);
        }
        worker();
    });
}

/// Spawn-overhead micro-bench: µs per small parallel region (32 tasks of
/// ~1µs each — MGS-column-dot scale) through the persistent pool vs the
/// scoped-spawn baseline it replaced.
fn region_overhead(threads: usize) -> (f64, f64) {
    const TASKS: usize = 32;
    const REGIONS: usize = 2_000;
    let src: Vec<f64> = (0..TASKS * 256).map(|i| (i % 17) as f64 * 0.25).collect();
    let sink: Vec<AtomicUsize> = (0..TASKS).map(|_| AtomicUsize::new(0)).collect();
    let task = |k: usize| {
        let s: f64 = src[k * 256..(k + 1) * 256].iter().sum();
        sink[k].store(s as usize, Ordering::Relaxed);
    };
    let exec = ExecPolicy::with_threads(threads);
    let pool = cse::util::timer::bench(3, || {
        for _ in 0..REGIONS {
            exec.run_indexed(TASKS, &task);
        }
    });
    let scoped = cse::util::timer::bench(3, || {
        for _ in 0..REGIONS {
            scoped_run_indexed(threads, TASKS, &task);
        }
    });
    (
        pool.mean_secs / REGIONS as f64 * 1e6,
        scoped.mean_secs / REGIONS as f64 * 1e6,
    )
}

/// Allocations per `apply_series` call (order-`order` Chebyshev-style
/// recursion over a d-column block): the throwaway-buffer path vs the
/// workspace path after warm-up. The latter must be **zero** — that is
/// the zero-steady-state-allocation acceptance check.
fn recursion_allocs(na: &Csr, x: &Mat, order: usize, exec: &ExecPolicy) -> (f64, f64) {
    let series = legendre::step_coeffs(order, 0.8);
    let reps = 10;
    let mut mv = 0usize;
    // Throwaway-buffer path (fresh Workspace per call).
    std::hint::black_box(cse::embed::fastembed::apply_series(na, &series, x, &mut mv, exec));
    let before = allocs_now();
    for _ in 0..reps {
        std::hint::black_box(cse::embed::fastembed::apply_series(na, &series, x, &mut mv, exec));
    }
    let fresh = (allocs_now() - before) as f64 / reps as f64;
    // Workspace path, warmed.
    let mut ws = cse::par::Workspace::new();
    for _ in 0..2 {
        let e = cse::embed::fastembed::apply_series_ws(na, &series, x, &mut mv, exec, &mut ws);
        ws.give_mat(e);
    }
    let before = allocs_now();
    for _ in 0..reps {
        let e = cse::embed::fastembed::apply_series_ws(na, &series, x, &mut mv, exec, &mut ws);
        ws.give_mat(e);
    }
    let warm = (allocs_now() - before) as f64 / reps as f64;
    (fresh, warm)
}

/// Parallel-execution-layer bench: SpMM GFLOP/s and embed wall-clock at
/// 1/2/4 threads on the n=100k synthetic serving graph, plus the
/// pre-refactor serial SpMM loop inlined as a reference so regressions of
/// the 1-thread path are visible; a d=128 column-tiled headroom row
/// (`spmm_tiled_gflops` — the register-blocked lanes vs the scalar
/// reference, bitwise-checked); sparse-format rows (CSR vs SELL-C-σ at
/// d=128 on the uniform and a power-law graph, bitwise-asserted, plus
/// the autotuner's pick on the power-law graph); fused-step accounting
/// (`fused_step_passes` — every interior recurrence step must arrive
/// through the one-pass axpby entry); region-dispatch overhead of the
/// persistent pool vs the scoped-spawn baseline; and allocs/iteration of
/// the recursion with and without workspace reuse. Appends a trajectory
/// entry to BENCH_kernels.json (and writes bench_out/kernels.tsv) so the
/// kernel trend stays monotone across perf PRs.
fn kernels() {
    let n = bench_n(100_000);
    let d = 64;
    let reps = 5;
    let thread_counts = [1usize, 2, 4];
    let mut rng = Rng::new(9);
    let g = gen::sbm_by_degree(&mut rng, n, (n / 200).max(2), 8.0, 0.8);
    let na = graph::normalized_adjacency(&g.adj);
    let x = Mat::randn(&mut rng, n, d);
    let nnz = na.nnz();
    let flops = (2 * nnz * d) as f64;
    println!(
        "SpMM workload: n={n} nnz={nnz} d={d} | host parallelism = {}",
        std::thread::available_parallelism().map_or(0, |c| c.get())
    );

    // The pre-refactor serial kernel, verbatim: whole-matrix row loop,
    // no partitioning. The threads=1 path must stay within ~5% of this.
    let mut y_ref = Mat::zeros(n, d);
    let reference = cse::util::timer::bench(reps, || {
        y_ref.data.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..na.rows {
            let (idx, val) = na.row(i);
            let yrow = &mut y_ref.data[i * d..(i + 1) * d];
            for (&j, &aij) in idx.iter().zip(val) {
                let xrow = &x.data[j as usize * d..(j as usize + 1) * d];
                for (yv, xv) in yrow.iter_mut().zip(xrow) {
                    *yv += aij * xv;
                }
            }
        }
    });

    struct KernelRow {
        threads: usize,
        spmm_secs: f64,
        embed_secs: f64,
    }
    let mut rows: Vec<KernelRow> = Vec::new();
    let mut check = Mat::zeros(n, d);
    na.spmm_into(&x, &mut check);
    for &threads in &thread_counts {
        let exec = ExecPolicy::with_threads(threads);
        let mut y = Mat::zeros(n, d);
        let spmm = cse::util::timer::bench(reps, || na.spmm_into_with(&x, &mut y, &exec));
        assert_eq!(y.data, check.data, "threaded SpMM must be bitwise-identical");

        let fe = FastEmbed::new(Params { d: 32, order: 60, cascade: 2, exec, ..Params::default() });
        let mut rng_e = Rng::new(77);
        let embed = cse::util::timer::bench(1, || {
            fe.embed(&na, &SpectralFn::Step { c: 0.75 }, &mut rng_e)
        });
        rows.push(KernelRow { threads, spmm_secs: spmm.mean_secs, embed_secs: embed.mean_secs });
    }

    let base_spmm = rows[0].spmm_secs;
    let base_embed = rows[0].embed_secs;
    println!(
        "\n{:<28} {:>10} {:>10} {:>9} {:>10} {:>9}",
        "variant", "spmm", "GFLOP/s", "speedup", "embed", "speedup"
    );
    println!(
        "{:<28} {:>8.1}ms {:>10.2} {:>9} {:>10} {:>9}",
        "reference (pre-refactor)",
        reference.mean_secs * 1e3,
        flops / reference.mean_secs / 1e9,
        "-",
        "-",
        "-"
    );
    let mut tsv = Vec::new();
    for r in &rows {
        println!(
            "{:<28} {:>8.1}ms {:>10.2} {:>8.2}x {:>9.2}s {:>8.2}x",
            format!("{} thread(s)", r.threads),
            r.spmm_secs * 1e3,
            flops / r.spmm_secs / 1e9,
            base_spmm / r.spmm_secs,
            r.embed_secs,
            base_embed / r.embed_secs
        );
        tsv.push(vec![
            r.threads as f64,
            r.spmm_secs,
            flops / r.spmm_secs / 1e9,
            base_spmm / r.spmm_secs,
            r.embed_secs,
            base_embed / r.embed_secs,
        ]);
    }
    let serial_ratio = rows[0].spmm_secs / reference.mean_secs;
    println!(
        "\n1-thread vs pre-refactor reference: {serial_ratio:.3}x (want <= 1.05); \
         4-thread SpMM speedup: {:.2}x",
        base_spmm / rows.last().unwrap().spmm_secs
    );
    io::write_tsv(
        Path::new("bench_out/kernels.tsv"),
        &["threads", "spmm_secs", "spmm_gflops", "spmm_speedup", "embed_secs", "embed_speedup"],
        &tsv,
    )
    .unwrap();

    // Column-tiled headroom at d=128: the scalar reference re-reads each
    // nonzero's (u32 index, f64 value) once per column; the shipped
    // kernel amortizes the load across register-blocked lanes of 8. Both
    // accumulate per output element in identical nonzero order, so the
    // results must match bitwise.
    let d_wide = 128;
    let xw = Mat::randn(&mut rng, n, d_wide);
    let mut yw_ref = Mat::zeros(n, d_wide);
    let reference_wide = cse::util::timer::bench(reps, || {
        yw_ref.data.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..na.rows {
            let (idx, val) = na.row(i);
            let yrow = &mut yw_ref.data[i * d_wide..(i + 1) * d_wide];
            for (&j, &aij) in idx.iter().zip(val) {
                let xrow = &xw.data[j as usize * d_wide..(j as usize + 1) * d_wide];
                for (yv, xv) in yrow.iter_mut().zip(xrow) {
                    *yv += aij * xv;
                }
            }
        }
    });
    let mut yw = Mat::zeros(n, d_wide);
    let tiled = cse::util::timer::bench(reps, || na.spmm_into(&xw, &mut yw));
    assert_eq!(yw.data, yw_ref.data, "tiled kernel must match the scalar reference bitwise");
    let flops_wide = (2 * nnz * d_wide) as f64;
    let spmm_tiled_gflops = flops_wide / tiled.mean_secs / 1e9;
    let tiled_speedup_d128 = reference_wide.mean_secs / tiled.mean_secs;
    println!(
        "\ncolumn-tiled SpMM @ d={d_wide}: {:.1}ms ({spmm_tiled_gflops:.2} GFLOP/s), \
         scalar reference {:.1}ms -> {tiled_speedup_d128:.2}x (want >= 1.3x)",
        tiled.mean_secs * 1e3,
        reference_wide.mean_secs * 1e3
    );

    // Sparse-format comparison at d=128, two degree regimes. On the
    // uniform-degree SBM graph above CSR is already well shaped — the CI
    // gate only holds SELL-C-σ to >= 0.95x of it. On a power-law
    // Barabási–Albert graph the σ-window sort packs hub and leaf rows
    // into separate slices and SELL should win outright (the tentpole's
    // >= 1.2x acceptance row). Both are asserted bitwise against CSR.
    let exec1 = ExecPolicy::serial();
    let mut ws = cse::par::Workspace::new();
    let sell = SellCs::from_csr_default(&na).unwrap();
    let mut yw_sell = Mat::zeros(n, d_wide);
    let sell_uni = cse::util::timer::bench(reps, || {
        sell.spmm_into_ws(&xw, &mut yw_sell, &exec1, &mut ws)
    });
    assert_eq!(yw_sell.data, yw_ref.data, "SELL must match CSR bitwise (uniform)");
    let mut yw_csr = Mat::zeros(n, d_wide);
    let csr_uni = cse::util::timer::bench(reps, || {
        na.spmm_into_ws(&xw, &mut yw_csr, &exec1, &mut ws)
    });
    let format_speedup_sell_vs_csr = csr_uni.mean_secs / sell_uni.mean_secs;

    let n_pl = (n / 2).max(1_000);
    let g_pl = gen::barabasi_albert(&mut rng, n_pl, 8);
    let na_pl = graph::normalized_adjacency(&g_pl.adj);
    let nnz_pl = na_pl.nnz();
    let sell_pl = SellCs::from_csr_default(&na_pl).unwrap();
    let x_pl = Mat::randn(&mut rng, n_pl, d_wide);
    let mut y_pl_csr = Mat::zeros(n_pl, d_wide);
    let csr_pl = cse::util::timer::bench(reps, || {
        na_pl.spmm_into_ws(&x_pl, &mut y_pl_csr, &exec1, &mut ws)
    });
    let mut y_pl_sell = Mat::zeros(n_pl, d_wide);
    let sell_pl_t = cse::util::timer::bench(reps, || {
        sell_pl.spmm_into_ws(&x_pl, &mut y_pl_sell, &exec1, &mut ws)
    });
    assert_eq!(y_pl_sell.data, y_pl_csr.data, "SELL must match CSR bitwise (power-law)");
    let flops_pl = (2 * nnz_pl * d_wide) as f64;
    let format_speedup_sell_vs_csr_powerlaw = csr_pl.mean_secs / sell_pl_t.mean_secs;
    println!(
        "\n{:<34} {:>10} {:>10} {:>9} {:>9}",
        "format @ d=128", "csr", "sell", "speedup", "padding"
    );
    println!(
        "{:<34} {:>7.2} GF {:>7.2} GF {:>8.2}x {:>8.1}%",
        format!("uniform SBM (cv={:.2})", cse::sparse::degree_cv(&na)),
        flops_wide / csr_uni.mean_secs / 1e9,
        flops_wide / sell_uni.mean_secs / 1e9,
        format_speedup_sell_vs_csr,
        100.0 * sell.padding_ratio()
    );
    println!(
        "{:<34} {:>7.2} GF {:>7.2} GF {:>8.2}x {:>8.1}%",
        format!("power-law BA (cv={:.2})", cse::sparse::degree_cv(&na_pl)),
        flops_pl / csr_pl.mean_secs / 1e9,
        flops_pl / sell_pl_t.mean_secs / 1e9,
        format_speedup_sell_vs_csr_powerlaw,
        100.0 * sell_pl.padding_ratio()
    );

    // Autotune point on the power-law graph, recorded in the trajectory
    // so regressions of the sweep itself (cost or pick) are visible.
    let tp = tune::tune(&na_pl, d_wide);
    let tuned_format = match tp.format {
        tune::TunedFormat::Sell => "sell-c-sigma",
        tune::TunedFormat::Csr => "csr",
    };
    println!(
        "autotune (power-law, d={d_wide}): {tuned_format} max_tile={} row_block_nnz={} \
         (csr {:.2} GF, sell {:.2} GF; swept in {:.1} ms)",
        tp.cfg.max_tile, tp.cfg.row_block_nnz, tp.csr_gflops, tp.sell_gflops, tp.tune_ms
    );

    // NUMA measurement set: d=128 SpMM through first-touch-placed arrays
    // vs the freshly-built baseline, same threaded policy, both formats.
    // Placement is a verbatim repack, so every output is asserted bitwise
    // against the scalar reference. On single-node hosts the repack lands
    // on the same node and the CI gate only requires parity
    // (numa_speedup >= 0.98); on multi-node hosts it should win.
    let topo = cse::par::topo::detect();
    let exec_numa = ExecPolicy::with_threads(4.min(topo.physical_cores().max(1)));
    let mut y_numa = Mat::zeros(n, d_wide);
    let csr_numa_base = cse::util::timer::bench(reps, || {
        na.spmm_into_ws(&xw, &mut y_numa, &exec_numa, &mut ws)
    });
    assert_eq!(y_numa.data, yw_ref.data, "threaded CSR baseline must match reference bitwise");
    let mut na_placed = na.clone();
    na_placed.place(&exec_numa);
    let csr_numa_placed = cse::util::timer::bench(reps, || {
        na_placed.spmm_into_ws(&xw, &mut y_numa, &exec_numa, &mut ws)
    });
    assert_eq!(y_numa.data, yw_ref.data, "placed CSR must be bitwise-identical");
    let mut sell_placed = sell.clone();
    sell_placed.place(&exec_numa);
    let sell_numa_base = cse::util::timer::bench(reps, || {
        sell.spmm_into_ws(&xw, &mut y_numa, &exec_numa, &mut ws)
    });
    assert_eq!(y_numa.data, yw_ref.data, "threaded SELL baseline must match reference bitwise");
    let sell_numa_placed = cse::util::timer::bench(reps, || {
        sell_placed.spmm_into_ws(&xw, &mut y_numa, &exec_numa, &mut ws)
    });
    assert_eq!(y_numa.data, yw_ref.data, "placed SELL must be bitwise-identical");
    let numa_speedup_csr = csr_numa_base.mean_secs / csr_numa_placed.mean_secs;
    let numa_speedup_sell = sell_numa_base.mean_secs / sell_numa_placed.mean_secs;
    let numa_speedup = numa_speedup_csr.min(numa_speedup_sell);
    println!(
        "\nNUMA placement @ d={d_wide} ({} node(s), {} physical cores, pinned={}): \
         csr {numa_speedup_csr:.2}x, sell {numa_speedup_sell:.2}x \
         (single-node gate: >= 0.98x)",
        topo.num_nodes(),
        topo.physical_cores(),
        cse::par::affinity::pinning_enabled()
    );

    // Fused-step accounting: wrap the operator and count which entry
    // point the three-term recurrence drives. Every interior step must
    // arrive through the fused axpby entry — one output pass, where the
    // pre-rework loop took three (SpMM + scale + subtract sweeps).
    struct CountingOp<'a> {
        inner: &'a Csr,
        fused: AtomicUsize,
        plain: AtomicUsize,
    }
    impl Operator for CountingOp<'_> {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn apply_into(&self, x: &Mat, y: &mut Mat, exec: &ExecPolicy) {
            self.plain.fetch_add(1, Ordering::Relaxed);
            self.inner.apply_into(x, y, exec);
        }
        fn apply_into_ws(
            &self,
            x: &Mat,
            y: &mut Mat,
            exec: &ExecPolicy,
            ws: &mut cse::par::Workspace,
        ) {
            self.plain.fetch_add(1, Ordering::Relaxed);
            self.inner.apply_into_ws(x, y, exec, ws);
        }
        fn apply_axpby_into_ws(
            &self,
            x: &Mat,
            alpha: f64,
            beta: f64,
            z: &Mat,
            y: &mut Mat,
            exec: &ExecPolicy,
            ws: &mut cse::par::Workspace,
        ) {
            self.fused.fetch_add(1, Ordering::Relaxed);
            self.inner.apply_axpby_into_ws(x, alpha, beta, z, y, exec, ws);
        }
        fn nnz(&self) -> usize {
            Csr::nnz(self.inner)
        }
    }
    let counting =
        CountingOp { inner: &na, fused: AtomicUsize::new(0), plain: AtomicUsize::new(0) };
    let series = legendre::step_coeffs(20, 0.8);
    let mut mv = 0usize;
    let q0 = Mat::randn(&mut rng, n, 8);
    std::hint::black_box(cse::embed::fastembed::apply_series(
        &counting,
        &series,
        &q0,
        &mut mv,
        &ExecPolicy::serial(),
    ));
    let fused_calls = counting.fused.load(Ordering::Relaxed);
    let plain_calls = counting.plain.load(Ordering::Relaxed);
    assert_eq!(
        fused_calls,
        series.coeffs.len() - 2,
        "every interior recurrence step must take the fused entry"
    );
    assert_eq!(plain_calls, 1, "only the q1 = S q0 bootstrap may use the plain entry");
    let fused_step_passes = 1usize;
    println!(
        "fused recurrence: {fused_calls} interior steps fused, {plain_calls} plain bootstrap \
         -> {fused_step_passes} output pass/step (was 3)"
    );

    // Region-dispatch overhead: persistent pool vs scoped-spawn baseline
    // on 32-task micro-regions (the pool must win — that is the tentpole).
    println!("\n{:<12} {:>14} {:>14} {:>9}", "dispatch", "pool µs/reg", "scoped µs/reg", "speedup");
    let mut dispatch_rows: Vec<(usize, f64, f64)> = Vec::new();
    for &threads in &[2usize, 4] {
        let (pool_us, scoped_us) = region_overhead(threads);
        println!(
            "{:<12} {pool_us:>14.2} {scoped_us:>14.2} {:>8.2}x",
            format!("{threads} threads"),
            scoped_us / pool_us
        );
        dispatch_rows.push((threads, pool_us, scoped_us));
    }

    // Allocation behaviour of the recursion's steady state.
    let x8 = Mat::randn(&mut rng, n, 8);
    println!("\n{:<26} {:>16} {:>16}", "recursion allocs/iter", "fresh buffers", "warm workspace");
    let mut alloc_rows: Vec<(usize, f64, f64)> = Vec::new();
    for &threads in &thread_counts {
        let exec = ExecPolicy::with_threads(threads);
        let (fresh, warm) = recursion_allocs(&na, &x8, 20, &exec);
        println!("{:<26} {fresh:>16.1} {warm:>16.1}", format!("{threads} thread(s), L=20 d=8"));
        alloc_rows.push((threads, fresh, warm));
    }
    println!("(warm workspace column must be 0 — the zero-steady-state-allocation check)");

    // Instrumented pass, deliberately AFTER every timed row above (span
    // overhead must not touch the timings, and region_overhead must run
    // with stats off): one 4-thread embed with stage histograms on, its
    // delta recorded into the trajectory entry as a per-stage breakdown.
    cse::obs::set_stats(true);
    let stage_base = stage_baseline();
    {
        let fe = FastEmbed::new(Params {
            d: 32,
            order: 60,
            cascade: 2,
            exec: ExecPolicy::with_threads(4),
            ..Params::default()
        });
        let mut rng_e = Rng::new(78);
        std::hint::black_box(fe.embed(&na, &SpectralFn::Step { c: 0.75 }, &mut rng_e));
    }
    let stages = stage_delta_json(&stage_base);
    cse::obs::set_stats(false);

    // Machine-readable trajectory: append this run to BENCH_kernels.json
    // so perf PRs can be checked for monotone kernel throughput.
    let obj = |pairs: Vec<(&str, Json)>| {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    };
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("threads", Json::Num(r.threads as f64)),
                ("spmm_secs", Json::Num(r.spmm_secs)),
                ("spmm_gflops", Json::Num(flops / r.spmm_secs / 1e9)),
                ("spmm_speedup_vs_1", Json::Num(base_spmm / r.spmm_secs)),
                ("embed_secs", Json::Num(r.embed_secs)),
                ("embed_speedup_vs_1", Json::Num(base_embed / r.embed_secs)),
            ])
        })
        .collect();
    let dispatch_json: Vec<Json> = dispatch_rows
        .iter()
        .map(|&(threads, pool_us, scoped_us)| {
            obj(vec![
                ("threads", Json::Num(threads as f64)),
                ("pool_us_per_region", Json::Num(pool_us)),
                ("scoped_us_per_region", Json::Num(scoped_us)),
            ])
        })
        .collect();
    let alloc_json: Vec<Json> = alloc_rows
        .iter()
        .map(|&(threads, fresh, warm)| {
            obj(vec![
                ("threads", Json::Num(threads as f64)),
                ("allocs_per_iter_fresh", Json::Num(fresh)),
                ("allocs_per_iter_warm_workspace", Json::Num(warm)),
            ])
        })
        .collect();
    let entry = obj(vec![
        ("n", Json::Num(n as f64)),
        ("nnz", Json::Num(nnz as f64)),
        ("d", Json::Num(d as f64)),
        (
            "host_threads",
            Json::Num(std::thread::available_parallelism().map_or(0.0, |c| c.get() as f64)),
        ),
        ("spmm_reference_secs", Json::Num(reference.mean_secs)),
        ("serial_ratio_vs_reference", Json::Num(serial_ratio)),
        ("spmm_tiled_gflops", Json::Num(spmm_tiled_gflops)),
        ("spmm_reference_d128_secs", Json::Num(reference_wide.mean_secs)),
        ("tiled_speedup_vs_reference_d128", Json::Num(tiled_speedup_d128)),
        ("format_speedup_sell_vs_csr", Json::Num(format_speedup_sell_vs_csr)),
        (
            "format_speedup_sell_vs_csr_powerlaw",
            Json::Num(format_speedup_sell_vs_csr_powerlaw),
        ),
        ("sell_padding_ratio_powerlaw", Json::Num(sell_pl.padding_ratio())),
        ("numa_speedup", Json::Num(numa_speedup)),
        ("numa_speedup_csr", Json::Num(numa_speedup_csr)),
        ("numa_speedup_sell", Json::Num(numa_speedup_sell)),
        ("numa_place", Json::Bool(true)),
        ("pin", Json::Bool(cse::par::affinity::pinning_enabled())),
        (
            "topology",
            obj(vec![
                ("nodes", Json::Num(topo.num_nodes() as f64)),
                ("physical_cores", Json::Num(topo.physical_cores() as f64)),
                ("smt", Json::Bool(topo.smt())),
            ]),
        ),
        (
            "autotune",
            obj(vec![
                ("format", Json::Str(tuned_format.to_string())),
                ("max_tile", Json::Num(tp.cfg.max_tile as f64)),
                ("row_block_nnz", Json::Num(tp.cfg.row_block_nnz as f64)),
                ("csr_gflops", Json::Num(tp.csr_gflops)),
                ("sell_gflops", Json::Num(tp.sell_gflops)),
                ("tune_ms", Json::Num(tp.tune_ms)),
            ]),
        ),
        ("fused_step_passes", Json::Num(fused_step_passes as f64)),
        ("results", Json::Arr(json_rows)),
        ("dispatch", Json::Arr(dispatch_json)),
        ("recursion_allocs", Json::Arr(alloc_json)),
        ("stages", stages),
    ]);
    // Preserve any prior trajectory (a legacy single-run file contributes
    // its results as entry zero).
    let prior = std::fs::read_to_string("BENCH_kernels.json")
        .ok()
        .and_then(|s| Json::parse(&s).ok());
    let mut trajectory: Vec<Json> = match &prior {
        Some(j) => match j.get("trajectory").and_then(|t| t.as_arr()) {
            Some(entries) => entries.to_vec(),
            None if j.get("results").is_some() => vec![j.clone()],
            None => Vec::new(),
        },
        None => Vec::new(),
    };
    trajectory.push(entry);
    let top = obj(vec![
        ("bench", Json::Str("kernels".to_string())),
        (
            "note",
            Json::Str(
                "appended per `cargo bench -- kernels` run; keep spmm_gflops, \
                 spmm_tiled_gflops, dispatch pool-vs-scoped, and warm-workspace allocs \
                 (= 0) monotone across perf PRs; fused_step_passes must stay 1; \
                 format_speedup_sell_vs_csr must stay >= 0.95 on the uniform graph"
                    .to_string(),
            ),
        ),
        ("trajectory", Json::Arr(trajectory)),
    ]);
    std::fs::write("BENCH_kernels.json", top.to_string()).unwrap();
    println!("-> wrote bench_out/kernels.tsv and appended to BENCH_kernels.json");
}

// ------------------------------------------------------------------ §Perf

/// §Perf: the SpMM hot path. Compares the naive per-column matvec loop
/// (what a straightforward port of Algorithm 1 does) against the blocked
/// row-major SpMM the library ships, plus allocation behaviour of the
/// recursion driver. Reports effective GFLOP/s and GB/s.
fn perf() {
    let n = bench_n(20_000);
    let deg = 8;
    let d = 64;
    let reps = 5;
    let mut rng = Rng::new(7);
    let g = gen::sbm_by_degree(&mut rng, n, 100, deg as f64 - 2.0, 2.0);
    let na = graph::normalized_adjacency(&g.adj);
    let x = Mat::randn(&mut rng, n, d);
    let nnz = na.nnz();
    println!("SpMM workload: n={n} nnz={nnz} d={d} ({} per product)\n", cse::util::human_bytes(8 * nnz));

    // Variant 1: naive — d independent matvecs (column-major access).
    let naive = cse::util::timer::bench(reps, || {
        let mut out = Mat::zeros(n, d);
        for j in 0..d {
            let col = x.col(j);
            let y = na.matvec(&col);
            out.set_col(j, &y);
        }
        out
    });

    // Variant 2: blocked row-major SpMM (the shipped hot path).
    let blocked = cse::util::timer::bench(reps, || na.spmm(&x));

    // Variant 3: blocked + preallocated output (the recursion's actual loop).
    let mut y = Mat::zeros(n, d);
    let prealloc = cse::util::timer::bench(reps, || na.spmm_into(&x, &mut y));

    let flops = (2 * nnz * d) as f64;
    let bytes = (12 * nnz + 8 * 2 * n * d) as f64; // idx+val stream + in/out blocks
    println!("{:<34} {:>10} {:>10} {:>10}", "variant", "mean", "GFLOP/s", "GB/s");
    for (name, s) in [
        ("naive per-column matvec", &naive),
        ("blocked row-major SpMM", &blocked),
        ("blocked + preallocated out", &prealloc),
    ] {
        println!(
            "{name:<34} {:>9.1}ms {:>10.2} {:>10.2}",
            s.mean_secs * 1e3,
            flops / s.mean_secs / 1e9,
            bytes / s.mean_secs / 1e9
        );
    }
    println!(
        "\nspeedup blocked vs naive: {:.2}x | prealloc vs blocked: {:.2}x",
        naive.mean_secs / blocked.mean_secs,
        blocked.mean_secs / prealloc.mean_secs
    );

    // End-to-end recursion throughput (the shipped driver).
    let series = legendre::step_coeffs(60, 0.8);
    let e2e = cse::util::timer::bench(3, || {
        let mut mv = 0;
        cse::embed::fastembed::apply_series(&na, &series, &x, &mut mv, &ExecPolicy::serial())
    });
    println!(
        "\nfull order-60 recursion over d={d}: {:.1}ms ({:.2} GFLOP/s sustained)",
        e2e.mean_secs * 1e3,
        (60.0 * flops) / e2e.mean_secs / 1e9
    );
    io::write_tsv(
        Path::new("bench_out/perf.tsv"),
        &["variant", "mean_secs"],
        &[
            vec![0.0, naive.mean_secs],
            vec![1.0, blocked.mean_secs],
            vec![2.0, prealloc.mean_secs],
            vec![3.0, e2e.mean_secs],
        ],
    )
    .unwrap();
    println!("-> wrote bench_out/perf.tsv (see EXPERIMENTS.md §Perf for the iteration log)");
}
