"""Legendre-series fitting of spectral weighing functions f(lambda).

Mirrors ``rust/src/poly`` (the runtime-path implementation); this copy feeds
the build-time L2 graphs and the pytest oracles. Coefficients follow the
paper §3.4:

    f~_L(x) = sum_{r=0}^{L} a(r) p(r, x),
    a(r) = (r + 1/2) * integral_{-1}^{1} p(r, x) f(x) dx,

minimizing Delta_L = (1/2) integral |f - f~_L|^2 dx (uniform eigenvalue
prior). For the step functions the paper actually uses, the integrals have a
closed form via the Legendre integral identity

    integral p(r, x) dx = (p(r+1, x) - p(r-1, x)) / (2r + 1),

so step coefficients are exact (no quadrature error). General f falls back
to fixed-order Gauss-Legendre quadrature on a fine partition.
"""

import numpy as np

from .kernels.ref import legendre_basis_ref


def step_coeffs(order, c, hi=1.0):
    """Exact Legendre coefficients of f(x) = I(c <= x <= hi) on [-1, 1]."""
    c = float(np.clip(c, -1.0, 1.0))
    hi = float(np.clip(hi, -1.0, 1.0))
    if hi <= c:
        return np.zeros(order + 1)
    # p(r, x) at both endpoints, orders 0..order+1.
    basis = legendre_basis_ref(np.array([c, hi]), order + 1)
    a = np.empty(order + 1)
    a[0] = 0.5 * (hi - c)
    for r in range(1, order + 1):
        # (r + 1/2) * [ (p(r+1,x) - p(r-1,x)) / (2r+1) ]_c^hi
        prim_hi = (basis[r + 1, 1] - basis[r - 1, 1]) / (2 * r + 1)
        prim_c = (basis[r + 1, 0] - basis[r - 1, 0]) / (2 * r + 1)
        a[r] = (r + 0.5) * (prim_hi - prim_c)
    return a


def fit_coeffs(f, order, panels=256, quad_order=8):
    """Legendre coefficients of arbitrary f via composite Gauss quadrature."""
    nodes, weights = np.polynomial.legendre.leggauss(quad_order)
    edges = np.linspace(-1.0, 1.0, panels + 1)
    mid = 0.5 * (edges[1:] + edges[:-1])
    half = 0.5 * (edges[1:] - edges[:-1])
    # All quadrature points (panels * quad_order,) and their weights.
    x = (mid[:, None] + half[:, None] * nodes[None, :]).ravel()
    w = (half[:, None] * weights[None, :]).ravel()
    fx = np.asarray([f(float(xi)) for xi in x])
    basis = legendre_basis_ref(x, order)  # (order+1, npts)
    r = np.arange(order + 1)
    return (r + 0.5) * (basis * (w * fx)[None, :]).sum(axis=1)


def recursion_scalars(order):
    """(c1(r), c2(r)) = (2 - 1/r, 1 - 1/r) for r = 1..order, as arrays."""
    r = np.arange(1, order + 1, dtype=np.float64)
    return 2.0 - 1.0 / r, 1.0 - 1.0 / r


def max_err(coeffs, f, grid=2001):
    """delta = max_x |f(x) - f~_L(x)| on a uniform grid (Theorem 1's bound)."""
    from .kernels.ref import poly_eval_legendre_ref

    x = np.linspace(-1.0, 1.0, grid)
    fx = np.asarray([f(float(xi)) for xi in x])
    return float(np.max(np.abs(fx - poly_eval_legendre_ref(coeffs, x))))
