"""Layer-1 Pallas kernel: implicit Gaussian-kernel block matvec ``K @ Q``.

Kernel-PCA (paper eq. (1)) needs spectral embeddings of the kernel matrix
``K(p, q) = exp(-||x_p - x_q||^2 / 2 alpha^2)`` over l points. Materializing
K is O(l^2) memory — the actual scalability wall for kernel PCA. FastEmbed
only ever needs ``K @ Q`` products, so this kernel computes them *without
materializing K*: every grid cell recomputes one (BI, BJ) tile of K from two
X tiles and immediately contracts it against a Q tile.

TPU mapping: the distance matrix of a tile is rank-3 computable from
``|x_i|^2 + |x_j|^2 - 2 x_i . x_j`` — one (BI, F) x (F, BJ) MXU matmul plus
broadcast adds; ``exp`` runs on the VPU; the contraction against Q is a
second MXU matmul. Arithmetic intensity is high (2 matmuls per K tile that
never touches HBM), exactly the FlashAttention-style recompute trade: HBM
traffic drops from O(l^2) to O(l * (F + d) * l / BJ).

VMEM per cell (f32): BI*F + BJ*F + BI*BJ (scratch) + BJ*BD + BI*BD
= 128*8 + 128*8 + 128*128 + 128*64 + 128*64 floats ~ 137 KiB << 16 MiB.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BI = 128  # output row tile
BJ = 128  # reduction (kernel column) tile


def _gauss_kernel(inv2a2_ref, xi_ref, xj_ref, q_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xi = xi_ref[...]
    xj = xj_ref[...]
    # Squared distances of the (BI, BJ) tile, via the rank-3 expansion.
    sq_i = jnp.sum(xi * xi, axis=1, keepdims=True)
    sq_j = jnp.sum(xj * xj, axis=1, keepdims=True)
    cross = jnp.dot(xi, xj.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(sq_i + sq_j.T - 2.0 * cross, 0.0)
    ktile = jnp.exp(-d2 * inv2a2_ref[0, 0])
    o_ref[...] += jnp.dot(ktile, q_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bi", "bj"))
def gauss_kernel_matvec(x, q, alpha, *, bi=None, bj=None):
    """``K @ Q`` for the Gaussian kernel on rows of x, K never materialized.

    Args:
      x:     (l, f) point cloud.
      q:     (l, d) block of vectors (e.g. the JL matrix Omega or a recursion
             state Q_r).
      alpha: kernel bandwidth (scalar).
      bi/bj: tile overrides for testing; clamped to the problem size.
    Returns:
      (l, d) product K @ Q in f32.
    """
    l, d = q.shape
    bi = min(bi or BI, l)
    bj = min(bj or BJ, l)
    assert l % bi == 0 and l % bj == 0, (l, bi, bj)

    inv2a2 = (1.0 / (2.0 * jnp.asarray(alpha, jnp.float32) ** 2)).reshape(1, 1)
    f = x.shape[1]
    grid = (l // bi, l // bj)
    return pl.pallas_call(
        _gauss_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # 1/(2 alpha^2)
            pl.BlockSpec((bi, f), lambda i, j: (i, 0)),  # X row tile
            pl.BlockSpec((bj, f), lambda i, j: (j, 0)),  # X col tile
            pl.BlockSpec((bj, d), lambda i, j: (j, 0)),  # Q tile
        ],
        out_specs=pl.BlockSpec((bi, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, d), jnp.float32),
        interpret=True,
    )(inv2a2, x, x, q)
