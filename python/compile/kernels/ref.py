"""Pure-jnp reference oracles for the Pallas kernels and the L2 model.

Everything in this file is deliberately the *simplest possible* correct
implementation: dense, un-tiled, no scan. The pytest suite asserts that the
Pallas kernels (``legendre_step.py``, ``gauss_kernel.py``) and the L2 model
graphs (``model.py``) match these oracles to float tolerance.
"""

import jax.numpy as jnp
import numpy as np


def legendre_step_ref(s, q_prev, q_prev2, c1, c2):
    """One Legendre three-term recursion step: ``c1 * (S @ Qp) - c2 * Qpp``."""
    return c1 * (s @ q_prev) - c2 * q_prev2


def gauss_kernel_matvec_ref(x, q, alpha):
    """``K @ Q`` with the Gaussian kernel K(p,q) = exp(-||x_p-x_q||^2 / 2a^2).

    Materializes the full l x l kernel matrix — the thing the Pallas kernel
    exists to avoid — which makes it a good oracle.
    """
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    k = jnp.exp(-jnp.maximum(d2, 0.0) / (2.0 * alpha * alpha))
    return k @ q


def legendre_basis_ref(x, order):
    """Legendre polynomials p(r, x), r = 0..order, on scalar/array x (numpy).

    Recursion: p(r,x) = (2 - 1/r) x p(r-1,x) - (1 - 1/r) p(r-2,x).
    """
    x = np.asarray(x, dtype=np.float64)
    out = [np.ones_like(x), x.copy()]
    for r in range(2, order + 1):
        out.append((2.0 - 1.0 / r) * x * out[r - 1] - (1.0 - 1.0 / r) * out[r - 2])
    return np.stack(out[: order + 1], axis=0)


def poly_eval_legendre_ref(coeffs, x):
    """Evaluate the Legendre series sum_r a(r) p(r,x) pointwise (numpy)."""
    basis = legendre_basis_ref(x, len(coeffs) - 1)
    return np.tensordot(np.asarray(coeffs, dtype=np.float64), basis, axes=1)


def fastembed_ref(s, omega, coeffs):
    """Direct (dense, eigh-based) evaluation of f~_L(S) @ Omega.

    Computes the polynomial of the matrix through its eigendecomposition —
    O(n^3) and exact, used as the oracle for the scan/Pallas recursion.
    """
    s = np.asarray(s, dtype=np.float64)
    lam, v = np.linalg.eigh(s)
    flam = poly_eval_legendre_ref(coeffs, lam)
    return (v * flam[None, :]) @ (v.T @ np.asarray(omega, dtype=np.float64))


def power_iteration_ref(s, v0, iters):
    """Spectral-norm lower bound: max column norm growth after `iters` steps."""
    s = np.asarray(s, dtype=np.float64)
    v = np.asarray(v0, dtype=np.float64)
    v = v / np.linalg.norm(v, axis=0, keepdims=True)
    est = 0.0
    for _ in range(iters):
        w = s @ v
        norms = np.linalg.norm(w, axis=0)
        est = float(np.max(norms))
        v = w / np.maximum(norms, 1e-30)
    return est
