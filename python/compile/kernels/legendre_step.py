"""Layer-1 Pallas kernel: one fused Legendre/Chebyshev recursion step.

Computes ``Q_r = c1 * (S @ Q_{r-1}) - c2 * Q_{r-2}`` — the inner loop of
Algorithm 1 of the paper (and, with (c1, c2) = (2, 1), of the Chebyshev
variant discussed in §4). This is the compute hot-spot of FastEmbed: the
whole algorithm is L of these steps per cascade stage.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the step is a dense
``(n, n) @ (n, d)`` matmul plus a scaled subtract, i.e. the canonical MXU
workload. We tile it ``(BN, BK) x (BK, BD)`` through VMEM with a 3-D grid
``(n/BN, d/BD, n/BK)``; the K axis is the *innermost* (fastest-moving) grid
dimension so the f32 output block stays resident in VMEM across the whole
K-reduction, and the ``-c2 * Q_{r-2}`` term is fused into the K==0
iteration instead of a separate pass over HBM.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated from the block geometry in
DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes. 128 is the MXU systolic-array edge; BD is
# the full embedding width d = O(log n), which comfortably fits VMEM:
# f32 VMEM footprint = BN*BK (S) + BK*BD (Qp) + 2*BN*BD (Qpp, O) floats
# = 128*128 + 128*64 + 2*128*64 = 48 KiB  << 16 MiB.
BN = 128
BK = 128


def _step_kernel(c1_ref, c2_ref, s_ref, qp_ref, qpp_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # Fuse the three-term tail into the first K iteration: the output
        # block starts at -c2 * Q_{r-2} instead of zero.
        o_ref[...] = -c2_ref[0, 0] * qpp_ref[...]

    o_ref[...] += c1_ref[0, 0] * jnp.dot(
        s_ref[...], qp_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bn", "bk", "bd"))
def legendre_step(s, q_prev, q_prev2, c1, c2, *, bn=None, bk=None, bd=None):
    """Fused recursion step as a Pallas call.

    Args:
      s:       (n, n) symmetric operator tile, ``||S|| <= 1``.
      q_prev:  (n, d) block ``Q_{r-1}``.
      q_prev2: (n, d) block ``Q_{r-2}``.
      c1, c2:  recursion scalars (2 - 1/r) and (1 - 1/r) — passed as scalars,
               reshaped to (1, 1) so they ride in SMEM-like blocks.
      bn/bk/bd: tile overrides (testing); default MXU-aligned, clamped to the
               problem size for small inputs.
    Returns:
      (n, d) block ``Q_r``.
    """
    n, d = q_prev.shape
    bn = min(bn or BN, n)
    bk = min(bk or BK, n)
    bd = min(bd or d, d)
    assert n % bn == 0 and n % bk == 0 and d % bd == 0, (n, d, bn, bk, bd)

    c1 = jnp.asarray(c1, jnp.float32).reshape(1, 1)
    c2 = jnp.asarray(c2, jnp.float32).reshape(1, 1)
    grid = (n // bn, d // bd, n // bk)
    return pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # c1
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),  # c2
            pl.BlockSpec((bn, bk), lambda i, j, k: (i, k)),  # S tile
            pl.BlockSpec((bk, bd), lambda i, j, k: (k, j)),  # Q_{r-1} tile
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),  # Q_{r-2} tile
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(c1, c2, s, q_prev, q_prev2)
