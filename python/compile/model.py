"""Layer-2 JAX model: the FastEmbed compute graphs (build-time only).

These are the computations that get AOT-lowered to HLO text by ``aot.py``
and executed from the Rust runtime (``rust/src/runtime``). Python never runs
on the request path — each function here is traced once per (shape, L)
combination at build time.

Graphs:
  * ``legendre_step_op``        — one recursion step (Pallas kernel, L1).
  * ``fastembed``               — full Algorithm 1: f~_L(S) Omega via
                                  ``lax.scan`` over the fused step kernel.
  * ``fastembed_cascade``       — §4 "denoising by cascading":
                                  (g~_{L/b}(S))^b Omega.
  * ``gauss_fastembed``         — kernel-PCA variant: the operator is the
                                  implicit Gaussian kernel (never
                                  materialized), Pallas kernel L1.
  * ``power_iteration``         — spectral-norm estimate (§4), the
                                  rescaling pre-pass.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.gauss_kernel import gauss_kernel_matvec
from .kernels.legendre_step import legendre_step


def fastembed(s, omega, coeffs):
    """f~_L(S) @ Omega by the Legendre three-term recursion (Algorithm 1).

    Args:
      s:      (n, n) symmetric, ||S|| <= 1.
      omega:  (n, d) JL projection block (d = O(log n) columns).
      coeffs: (L+1,) Legendre series coefficients a(r).
    Returns:
      (n, d) compressive embedding E~.
    """
    order = coeffs.shape[0] - 1
    q0 = omega
    e = coeffs[0] * q0
    if order == 0:
        return e
    q1 = s @ q0  # p(1, S) Omega = S Omega
    e = e + coeffs[1] * q1

    if order == 1:
        return e

    r = jnp.arange(2, order + 1, dtype=jnp.float32)
    c1 = 2.0 - 1.0 / r
    c2 = 1.0 - 1.0 / r

    def body(carry, inputs):
        q_prev, q_prev2, acc = carry
        a_r, c1_r, c2_r = inputs
        q = legendre_step(s, q_prev, q_prev2, c1_r, c2_r)
        return (q, q_prev, acc + a_r * q), None

    (_, _, e), _ = lax.scan(body, (q1, q0, e), (coeffs[2:], c1, c2))
    return e


def fastembed_cascade(s, omega, coeffs, b):
    """§4 cascading: apply the order-(L/b) polynomial of S, b times.

    ``coeffs`` fit g = f^{1/b}; the x^b nonlinearity re-sharpens the nulls
    of f that the low-order approximation would otherwise blur.
    """
    e = omega
    for _ in range(b):
        e = fastembed(s, e, coeffs)
    return e


def gauss_fastembed(x, omega, coeffs, alpha):
    """FastEmbed where S is the implicit (rescaled) Gaussian kernel operator.

    The operator passed to the recursion is K / kappa with K the Gaussian
    kernel on rows of x and kappa a caller-supplied bound on ||K|| folded
    into ``coeffs`` (the Rust coordinator rescales f accordingly, §3.4).
    Here we take the operator as K itself and assume coeffs were fit for the
    rescaled spectrum.
    """
    order = coeffs.shape[0] - 1
    q0 = omega
    e = coeffs[0] * q0
    if order == 0:
        return e
    q1 = gauss_kernel_matvec(x, q0, alpha)
    e = e + coeffs[1] * q1
    if order == 1:
        return e

    r = jnp.arange(2, order + 1, dtype=jnp.float32)
    c1 = 2.0 - 1.0 / r
    c2 = 1.0 - 1.0 / r

    def body(carry, inputs):
        q_prev, q_prev2, acc = carry
        a_r, c1_r, c2_r = inputs
        q = c1_r * gauss_kernel_matvec(x, q_prev, alpha) - c2_r * q_prev2
        return (q, q_prev, acc + a_r * q), None

    (_, _, e), _ = lax.scan(body, (q1, q0, e), (coeffs[2:], c1, c2))
    return e


def power_iteration(s, v0, iters=20):
    """Spectral-norm lower bound via `iters` power steps on a block v0.

    Returns (estimate, v_final). The paper (§4) runs 20 iterations on
    6 log n starting vectors and scales the estimate by 1.01.
    """

    def body(v, _):
        w = s @ v
        norms = jnp.linalg.norm(w, axis=0)
        est = jnp.max(norms)
        return w / jnp.maximum(norms, 1e-30), est

    v, ests = lax.scan(body, v0 / jnp.linalg.norm(v0, axis=0), None, length=iters)
    return ests[-1], v


def legendre_step_op(s, q_prev, q_prev2, c1, c2):
    """Single recursion step — the unit artifact the Rust loop drives.

    Keeping L on the Rust side (loop over this fixed-shape executable) lets
    one compiled artifact serve any polynomial order / weighing function.
    """
    return legendre_step(s, q_prev, q_prev2, c1, c2)
