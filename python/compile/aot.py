"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` and
NOT a serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit
instruction ids which the xla_extension 0.5.1 bundled with the `xla` crate
rejects (``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly (see /opt/xla-example/README.md).

Every entry point is lowered with ``return_tuple=True`` so the Rust side
unwraps with ``to_tuple1()`` uniformly. A ``manifest.json`` records the
parameter shapes for each artifact so ``rust/src/runtime`` can validate its
inputs before compile time.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, poly

# Tile geometry the Rust coordinator drives. One (n, d) unit of work; larger
# problems decompose into these tiles, larger d into column shards.
N = 256
D = 32
GAUSS_L = 256
GAUSS_F = 8
FULL_L = 16  # baked order for the fused full-recursion artifact
POWER_ITERS = 20
POWER_B = 16  # power-iteration block width ~ 6 log n


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# --- entry points -----------------------------------------------------------
# Scalars travel as small arrays ((2,), (1,)) — the Rust side builds them
# with Literal::vec1, avoiding rank-0 literal plumbing.


def step_entry(s, qp, qpp, c):
    """One Legendre step; c = [c1, c2]. The Rust loop drives arbitrary L."""
    return (model.legendre_step_op(s, qp, qpp, c[0], c[1]),)


def fastembed_entry(s, omega, coeffs):
    """Fused full recursion at baked order FULL_L (scan lives in HLO)."""
    return (model.fastembed(s, omega, coeffs),)


def gauss_matvec_entry(x, q, alpha):
    """Implicit Gaussian-kernel block matvec K @ Q (K never materialized)."""
    from .kernels.gauss_kernel import gauss_kernel_matvec

    return (gauss_kernel_matvec(x, q, alpha[0]),)


def gauss_fastembed_entry(x, omega, coeffs, alpha):
    """Fused kernel-PCA FastEmbed at baked order FULL_L."""
    return (model.gauss_fastembed(x, omega, coeffs, alpha[0]),)


def power_iter_entry(s, v0):
    """Spectral-norm estimate: (est as (1,), final block)."""
    est, v = model.power_iteration(s, v0, iters=POWER_ITERS)
    return (est.reshape(1), v)


ARTIFACTS = [
    # (name, fn, arg specs)
    (
        f"legendre_step_{N}x{D}",
        step_entry,
        [f32(N, N), f32(N, D), f32(N, D), f32(2)],
    ),
    (
        f"fastembed_{N}x{D}_L{FULL_L}",
        fastembed_entry,
        [f32(N, N), f32(N, D), f32(FULL_L + 1)],
    ),
    (
        f"gauss_matvec_{GAUSS_L}x{GAUSS_F}x{D}",
        gauss_matvec_entry,
        [f32(GAUSS_L, GAUSS_F), f32(GAUSS_L, D), f32(1)],
    ),
    (
        f"gauss_fastembed_{GAUSS_L}x{GAUSS_F}x{D}_L{FULL_L}",
        gauss_fastembed_entry,
        [f32(GAUSS_L, GAUSS_F), f32(GAUSS_L, D), f32(FULL_L + 1), f32(1)],
    ),
    (
        f"power_iter_{N}x{POWER_B}",
        power_iter_entry,
        [f32(N, N), f32(N, POWER_B)],
    ),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, fn, specs in ARTIFACTS:
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "params": [list(s.shape) for s in specs],
            "dtype": "f32",
        }
        print(f"wrote {path} ({len(text)} chars)")

    # Reference Legendre coefficients for the step function used by the
    # kernel-PCA example (f = I(lambda >= 0.5) at order FULL_L), so the Rust
    # side can cross-check its own closed-form coefficient computation.
    manifest["_ref_step_coeffs_L16_c0.5"] = list(
        map(float, poly.step_coeffs(FULL_L, 0.5))
    )
    manifest["_tile"] = {"n": N, "d": D, "gauss_l": GAUSS_L, "gauss_f": GAUSS_F,
                         "full_L": FULL_L, "power_iters": POWER_ITERS,
                         "power_b": POWER_B}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
