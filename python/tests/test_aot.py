"""AOT lowering sanity: every artifact lowers to parseable HLO text."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model, poly


@pytest.mark.parametrize("name,fn,specs", aot.ARTIFACTS, ids=[a[0] for a in aot.ARTIFACTS])
def test_artifact_lowers_to_hlo_text(name, fn, specs):
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # return_tuple=True: the root is a tuple
    assert "tuple" in text


def test_step_entry_numerics():
    """The exact computation the Rust runtime drives, checked in python."""
    n, d = aot.N, aot.D
    rng = np.random.default_rng(1)
    s = rng.standard_normal((n, n)).astype(np.float32)
    s = ((s + s.T) / 2 / np.abs(np.linalg.eigvalsh(s.astype(np.float64))).max()).astype(np.float32)
    qp = rng.standard_normal((n, d)).astype(np.float32)
    qpp = rng.standard_normal((n, d)).astype(np.float32)
    c = np.array([1.5, 0.5], dtype=np.float32)
    (out,) = aot.step_entry(s, qp, qpp, c)
    want = 1.5 * (s @ qp) - 0.5 * qpp
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_manifest_written(tmp_path):
    """Full aot main() writes all artifacts + manifest (slow-ish, once)."""
    out = tmp_path / "artifacts"
    argv = sys.argv
    sys.argv = ["aot", "--out", str(out)]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    for name, _, specs in aot.ARTIFACTS:
        assert name in manifest
        assert (out / manifest[name]["file"]).exists()
        assert manifest[name]["params"] == [list(s.shape) for s in specs]
