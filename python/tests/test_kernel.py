"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/tile sizes; assert_allclose against ref.py.
Kernels run under interpret=True (CPU), so keep shapes modest.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.legendre_step import legendre_step
from compile.kernels.gauss_kernel import gauss_kernel_matvec
from compile.kernels import ref

RNG = np.random.default_rng(0)


def _sym(n, dtype=np.float32):
    a = RNG.standard_normal((n, n)).astype(dtype)
    a = (a + a.T) / 2
    return a / (np.abs(np.linalg.eigvalsh(a.astype(np.float64))).max() + 1e-6)


# ---------------------------------------------------------------- legendre


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 16, 32, 64]),
    d=st.sampled_from([4, 8, 16]),
    r=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_legendre_step_matches_ref(n, d, r, seed):
    rng = np.random.default_rng(seed)
    s = _sym(n)
    qp = rng.standard_normal((n, d)).astype(np.float32)
    qpp = rng.standard_normal((n, d)).astype(np.float32)
    c1, c2 = 2.0 - 1.0 / r, 1.0 - 1.0 / r
    got = legendre_step(s, qp, qpp, c1, c2)
    want = ref.legendre_step_ref(s, qp, qpp, c1, c2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bn,bk,bd", [(8, 8, 4), (16, 32, 8), (32, 16, 16), (64, 64, 16)])
def test_legendre_step_tilings_agree(bn, bk, bd):
    """Tiling must not change the numbers (grid/BlockSpec correctness)."""
    n, d = 64, 16
    s = _sym(n)
    qp = RNG.standard_normal((n, d)).astype(np.float32)
    qpp = RNG.standard_normal((n, d)).astype(np.float32)
    got = legendre_step(s, qp, qpp, 1.5, 0.5, bn=bn, bk=bk, bd=bd)
    want = ref.legendre_step_ref(s, qp, qpp, 1.5, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_legendre_step_zero_c2_is_scaled_matmul():
    n, d = 16, 4
    s = _sym(n)
    qp = RNG.standard_normal((n, d)).astype(np.float32)
    qpp = RNG.standard_normal((n, d)).astype(np.float32)
    got = legendre_step(s, qp, qpp, 3.0, 0.0)
    np.testing.assert_allclose(np.asarray(got), 3.0 * (s @ qp), rtol=2e-4, atol=2e-4)


def test_legendre_step_identity_operator():
    """S = I: step reduces to c1*Qp - c2*Qpp exactly."""
    n, d = 32, 8
    s = np.eye(n, dtype=np.float32)
    qp = RNG.standard_normal((n, d)).astype(np.float32)
    qpp = RNG.standard_normal((n, d)).astype(np.float32)
    got = legendre_step(s, qp, qpp, 1.75, 0.75)
    np.testing.assert_allclose(np.asarray(got), 1.75 * qp - 0.75 * qpp, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- gauss


@settings(max_examples=20, deadline=None)
@given(
    l=st.sampled_from([8, 16, 32, 64]),
    f=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([4, 8]),
    alpha=st.floats(min_value=0.3, max_value=3.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gauss_matvec_matches_ref(l, f, d, alpha, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((l, f)).astype(np.float32)
    q = rng.standard_normal((l, d)).astype(np.float32)
    got = gauss_kernel_matvec(x, q, alpha)
    want = ref.gauss_kernel_matvec_ref(x, q, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bi,bj", [(8, 8), (16, 32), (32, 16), (64, 64)])
def test_gauss_matvec_tilings_agree(bi, bj):
    l, f, d = 64, 4, 8
    x = RNG.standard_normal((l, f)).astype(np.float32)
    q = RNG.standard_normal((l, d)).astype(np.float32)
    got = gauss_kernel_matvec(x, q, 1.0, bi=bi, bj=bj)
    want = ref.gauss_kernel_matvec_ref(x, q, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_gauss_matvec_wide_bandwidth_sums_rows():
    """alpha -> inf: K -> all-ones, so K @ Q -> column sums broadcast."""
    l, f, d = 16, 3, 4
    x = 0.01 * RNG.standard_normal((l, f)).astype(np.float32)
    q = RNG.standard_normal((l, d)).astype(np.float32)
    got = np.asarray(gauss_kernel_matvec(x, q, 1e4))
    want = np.tile(q.sum(axis=0), (l, 1))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_gauss_matvec_kernel_row_is_symmetric_psd_effect():
    """K is symmetric: (K Q)^T e_j == (K e_j)^T Q column-wise check."""
    l, f = 32, 4
    x = RNG.standard_normal((l, f)).astype(np.float32)
    q = np.eye(l, dtype=np.float32)[:, :8]
    kq = np.asarray(gauss_kernel_matvec(x, q, 1.2))  # first 8 columns of K
    k_full = np.asarray(ref.gauss_kernel_matvec_ref(x, np.eye(l, dtype=np.float32), 1.2))
    np.testing.assert_allclose(kq, k_full[:, :8], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(k_full, k_full.T, rtol=1e-4, atol=1e-4)
