"""L2 model graphs vs dense eigh-based oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model, poly
from compile.kernels import ref

RNG = np.random.default_rng(7)


def _sym(n, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    a = (a + a.T) / 2
    return (a / (np.abs(np.linalg.eigvalsh(a.astype(np.float64))).max() + 1e-6)).astype(np.float32)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([8, 16, 32]),
       d=st.sampled_from([4, 8]),
       order=st.integers(min_value=0, max_value=12),
       seed=st.integers(min_value=0, max_value=10**6))
def test_fastembed_matches_eigh_oracle(n, d, order, seed):
    rng = np.random.default_rng(seed)
    s = _sym(n, seed)
    omega = rng.choice([-1.0, 1.0], size=(n, d)).astype(np.float32) / np.sqrt(d)
    coeffs = poly.fit_coeffs(np.exp, order).astype(np.float32)
    got = np.asarray(model.fastembed(s, omega, jnp.asarray(coeffs)))
    want = ref.fastembed_ref(s, omega, coeffs)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_fastembed_order0_and_1():
    n, d = 16, 4
    s = _sym(n)
    omega = RNG.standard_normal((n, d)).astype(np.float32)
    a0 = np.array([0.7], dtype=np.float32)
    np.testing.assert_allclose(np.asarray(model.fastembed(s, omega, jnp.asarray(a0))),
                               0.7 * omega, rtol=1e-6)
    a1 = np.array([0.3, -1.2], dtype=np.float32)
    want = 0.3 * omega - 1.2 * (s @ omega)
    np.testing.assert_allclose(np.asarray(model.fastembed(s, omega, jnp.asarray(a1))),
                               want, rtol=1e-5, atol=1e-5)


def test_cascade_equals_repeated_application():
    """(g~(S))^b Omega == applying the order-L/b recursion b times."""
    n, d, order, b = 16, 4, 6, 3
    s = _sym(n, 3)
    omega = RNG.standard_normal((n, d)).astype(np.float32)
    coeffs = jnp.asarray(poly.fit_coeffs(lambda x: 0.5 * (x + 1), order).astype(np.float32))
    got = np.asarray(model.fastembed_cascade(s, omega, coeffs, b))
    want = omega
    for _ in range(b):
        want = ref.fastembed_ref(s, want, np.asarray(coeffs))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_cascade_sharpens_nulls():
    """§4: b=2 on g=f^(1/2) suppresses the f=0 band better than b=1 on f.

    Build a matrix with eigenvalues straddling the cut c=0.5 and compare the
    residual mass that leaks through the null band.
    """
    n, d, L = 32, 8, 12
    rng = np.random.default_rng(11)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    lam = np.concatenate([np.linspace(0.8, 0.95, 4), np.linspace(-0.4, 0.3, n - 4)])
    s = (q * lam) @ q.T
    s = s.astype(np.float32)
    omega = (rng.choice([-1, 1], size=(n, d)) / np.sqrt(d)).astype(np.float32)

    f = lambda x: 1.0 if x >= 0.5 else 0.0
    c_b1 = jnp.asarray(poly.step_coeffs(L, 0.5).astype(np.float32))
    c_b2 = jnp.asarray(poly.step_coeffs(L // 2, 0.5).astype(np.float32))  # g = f^(1/2) = f
    e_b1 = np.asarray(model.fastembed(s, omega, c_b1))
    e_b2 = np.asarray(model.fastembed_cascade(s, omega, c_b2, 2))
    exact = ref.fastembed_ref(s.astype(np.float64), omega, None) if False else None

    # Project embeddings onto the "noise" eigenvectors (lambda < 0.5): the
    # cascade must leak less.
    lam_f, v = np.linalg.eigh(s.astype(np.float64))
    noise = v[:, lam_f < 0.5]
    leak = lambda e: np.linalg.norm(noise.T @ e) / np.linalg.norm(e)
    assert leak(e_b2) < leak(e_b1)


def test_power_iteration_estimates_norm():
    n = 48
    s = _sym(n, 5) * 0.9
    true = np.abs(np.linalg.eigvalsh(s.astype(np.float64))).max()
    v0 = RNG.standard_normal((n, 8)).astype(np.float32)
    est, _ = model.power_iteration(s, v0, iters=30)
    est = float(est)
    assert est <= true * 1.001
    assert est >= true * 0.9


def test_gauss_fastembed_matches_dense_oracle():
    l, f, d, order = 32, 4, 8, 6
    rng = np.random.default_rng(9)
    x = rng.standard_normal((l, f)).astype(np.float32)
    alpha = 1.5
    # Dense kernel matrix, rescaled to ||K||<=1 like the coordinator does.
    kd = np.asarray(ref.gauss_kernel_matvec_ref(x, np.eye(l, dtype=np.float32), alpha))
    kappa = np.abs(np.linalg.eigvalsh(kd.astype(np.float64))).max() * 1.01
    omega = (rng.choice([-1, 1], size=(l, d)) / np.sqrt(d)).astype(np.float32)
    # Fit f on the *rescaled* spectrum: operator passed in is K, so fold the
    # 1/kappa into the polynomial argument.
    fcut = lambda y: 1.0 if y >= 0.2 else 0.0
    coeffs = poly.fit_coeffs(fcut, order).astype(np.float32)
    # Evaluate oracle on K/kappa, recursion on K/kappa by scaling x... the
    # recursion consumes K directly, so instead compare both on K/kappa via
    # linearity: run model on scaled operator using alpha trick is not
    # possible; instead validate model.gauss_fastembed against the same
    # recursion done densely with K.
    got = np.asarray(model.gauss_fastembed(x, omega, jnp.asarray(coeffs), alpha))
    want = ref.fastembed_ref(kd, omega, coeffs)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)
