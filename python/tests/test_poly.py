"""Legendre fitting (compile.poly) — closed forms vs quadrature vs decay."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import poly
from compile.kernels.ref import legendre_basis_ref, poly_eval_legendre_ref


def test_legendre_orthogonality():
    """integral p(k) p(l) = 2 I(k==l) / (2k+1) via fine quadrature."""
    x, w = np.polynomial.legendre.leggauss(64)
    basis = legendre_basis_ref(x, 8)
    gram = (basis * w[None, :]) @ basis.T
    want = np.diag([2.0 / (2 * k + 1) for k in range(9)])
    np.testing.assert_allclose(gram, want, atol=1e-12)


def test_legendre_matches_numpy():
    x = np.linspace(-1, 1, 101)
    ours = legendre_basis_ref(x, 6)
    for r in range(7):
        c = np.zeros(r + 1)
        c[r] = 1.0
        np.testing.assert_allclose(ours[r], np.polynomial.legendre.legval(x, c),
                                   atol=1e-12)


@settings(max_examples=30, deadline=None)
@given(c=st.floats(min_value=-0.95, max_value=0.95),
       order=st.integers(min_value=0, max_value=40))
def test_step_coeffs_match_quadrature(c, order):
    exact = poly.step_coeffs(order, c)
    quad = poly.fit_coeffs(lambda x: 1.0 if x >= c else 0.0, order, panels=512)
    # Quadrature sees the discontinuity mid-panel -> O(panel width) error.
    np.testing.assert_allclose(exact, quad, atol=3e-3)


def test_step_coeffs_empty_interval():
    np.testing.assert_allclose(poly.step_coeffs(10, 1.0), np.zeros(11))


def test_step_coeffs_full_interval_is_constant_one():
    a = poly.step_coeffs(12, -1.0)
    want = np.zeros(13)
    want[0] = 1.0
    np.testing.assert_allclose(a, want, atol=1e-12)


def test_delta_decreases_with_order_smooth():
    """Smooth f: delta(L) decays fast (§4 'smooth functions...')."""
    f = lambda x: np.exp(x)
    errs = [poly.max_err(poly.fit_coeffs(f, L), f) for L in (2, 4, 8, 12)]
    assert errs[0] > errs[1] > errs[2] > errs[3]
    assert errs[3] < 1e-8


def test_delta_nonincreasing_step():
    f = lambda x: 1.0 if x >= 0.3 else 0.0
    errs = [poly.max_err(poly.step_coeffs(L, 0.3), f) for L in (5, 20, 80)]
    # Step functions: maximum error at the discontinuity stays ~0.5 (Gibbs)
    # but the L2 error and off-jump error fall; check monotone L2 proxy.
    x = np.linspace(-1, 1, 4001)
    fx = np.asarray([f(v) for v in x])
    l2 = [np.sqrt(np.mean((fx - poly_eval_legendre_ref(poly.step_coeffs(L, 0.3), x)) ** 2))
          for L in (5, 20, 80)]
    assert l2[0] > l2[1] > l2[2]


def test_recursion_scalars():
    c1, c2 = poly.recursion_scalars(4)
    np.testing.assert_allclose(c1, [1.0, 1.5, 5.0 / 3, 1.75])
    np.testing.assert_allclose(c2, [0.0, 0.5, 2.0 / 3, 0.75])


def test_commute_time_fit_converges():
    """f(x) = 1/sqrt(1-x) truncated — the paper's commute-time weighting."""
    f = lambda x: 1.0 / np.sqrt(max(1.0 - x, 0.05))
    e8 = poly.max_err(poly.fit_coeffs(f, 8), f)
    e32 = poly.max_err(poly.fit_coeffs(f, 32), f)
    # The eps-clamp kink at x = 0.95 limits the rate; ~4x per 4x order.
    assert e32 < e8 * 0.3
