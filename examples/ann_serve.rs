//! Exact vs ANN serving, end to end: embed an SBM graph once, then
//! answer the same top-k workload through (a) the exact linear scan and
//! (b) the multi-table SimHash index, reporting throughput, latency
//! percentiles, recall@k and candidate-set sizes side by side.
//!
//! Run: `cargo run --release --example ann_serve -- [--n 20000] [--topk 10]`

use cse::coordinator::service::Query;
use cse::coordinator::{measure_serving, Coordinator, EmbedJob, SimilarityService};
use cse::embed::Params;
use cse::funcs::SpectralFn;
use cse::index::{evaluate_recall, AnnIndex, SimHashIndex, SimHashParams};
use cse::sparse::{gen, graph};
use cse::util::args::Args;
use cse::util::rng::Rng;
use cse::util::timer::Timer;
use cse::util::{human_bytes, human_secs};

fn main() {
    let a = Args::from_env(&[]).unwrap();
    let n = a.usize("n", 20_000).unwrap();
    let nq = a.usize("queries", 2_000).unwrap();
    let topk = a.usize("topk", 10).unwrap();
    let workers = a.usize("workers", 2).unwrap();

    let mut rng = Rng::new(a.u64("seed", 0).unwrap());
    let g = gen::sbm_by_degree(&mut rng, n, (n / 150).max(2), 8.0, 0.8);
    let na = graph::normalized_adjacency(&g.adj);
    println!("graph: n={n} nnz={}", na.nnz());

    let job = EmbedJob::new(
        Params { d: 64, order: 80, cascade: 2, ..Params::default() },
        SpectralFn::Step { c: 0.75 },
        1,
    );
    let t = Timer::start();
    let res = Coordinator::new(workers).run(&na, &job).expect("embed job failed");
    println!(
        "embedding: d={} in {} ({} matvecs)",
        res.e.cols,
        human_secs(t.elapsed_secs()),
        res.matvecs
    );
    let mut service = SimilarityService::new(res.e);

    let queries: Vec<Query> =
        (0..nq).map(|_| Query::TopK { i: rng.below(n), k: topk }).collect();
    let sample: Vec<usize> = (0..200).map(|_| rng.below(n)).collect();

    // Pass 1: exact scan (no index).
    let exact_qps = run_pass(&service, &queries, workers, "exact scan");

    // Pass 2: SimHash index at default parameters.
    let p = SimHashParams::default();
    let idx = SimHashIndex::build(service.embedding(), p);
    println!(
        "\nsimhash build: tables={} bits={} probes={} in {} ({})",
        p.tables,
        p.bits,
        p.probes,
        human_secs(idx.build_secs),
        human_bytes(idx.mem_bytes())
    );
    let rep = evaluate_recall(service.embedding(), service.norms(), &idx, &sample, topk);
    service.attach_index(Box::new(idx));
    let ann_qps = run_pass(&service, &queries, workers, "simhash");

    println!(
        "\nrecall@{}: mean {:.3}, min {:.3} ({:.1} candidates/query = {:.2}% of rows)",
        rep.k,
        rep.mean_recall,
        rep.min_recall,
        rep.mean_candidates,
        100.0 * rep.candidate_fraction
    );
    println!("speedup: {:.1}x qps over exact", ann_qps / exact_qps);
}

/// Measure the workload through the shared harness and print one line.
/// Returns batched QPS.
fn run_pass(
    service: &SimilarityService,
    queries: &[Query],
    workers: usize,
    label: &str,
) -> f64 {
    let s = measure_serving(service, queries, workers);
    println!(
        "{label:<12} {:>8.0} qps ({workers} workers) | serial p50 {:.0} µs, p99 {:.0} µs \
         | mean candidates {:.1}",
        s.qps_batch,
        s.p50_us,
        s.p99_us,
        s.mean_candidates,
    );
    s.qps_batch
}
