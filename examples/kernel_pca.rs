//! Kernel PCA through the full three-layer stack (paper eq. (1)):
//!
//!   L1  Pallas kernel `gauss_matvec` — implicit Gaussian-kernel product
//!       K@Q with K never materialized (python/compile/kernels/).
//!   L2  JAX graph AOT-lowered to `artifacts/gauss_matvec_*.hlo.txt`.
//!   L3  this binary: loads the artifact via PJRT, runs FastEmbed's
//!       recursion + spectral-norm estimation against the implicit
//!       operator, clusters the embedding, and cross-checks everything
//!       against a native dense oracle.
//!
//! Run: `make artifacts && cargo run --release --example kernel_pca`

use std::sync::Arc;

use cse::cluster::{kmeans, nmi, KmeansParams};
use cse::embed::fastembed::{apply_series, plan_scaled};
use cse::embed::norm::{spectral_norm, NormEstParams};
use cse::embed::op::{DenseOp, ScaledOp};
use cse::embed::omega::rademacher_omega;
use cse::funcs::SpectralFn;
use cse::par::ExecPolicy;
use cse::linalg::Mat;
use cse::poly::Basis;
use cse::runtime::ops::GaussKernelOp;
use cse::runtime::{Artifacts, Runtime};
use cse::sparse::gen::gaussian_mixture;
use cse::util::rng::Rng;
use cse::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let dir = Artifacts::default_dir();
    let arts = match Artifacts::load(&dir) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    };
    let rt = Arc::new(Runtime::cpu()?);
    let info = arts.find_prefix("gauss_matvec").expect("gauss artifact");
    let (l, feat) = (info.params[0][0], info.params[0][1]);
    let d = info.params[1][1];
    println!("artifact tile: l={l} features={feat} d={d}");

    // Point cloud: 4 well-separated Gaussian clusters in `feat` dims.
    let mut rng = Rng::new(3);
    let clusters = 4;
    let (pts, labels) = gaussian_mixture(&mut rng, l, feat, clusters, 5.0);
    let x = Mat::from_vec(l, feat, pts);
    let alpha = 2.0;

    // The implicit kernel operator, served by the Pallas/PJRT artifact.
    let op = GaussKernelOp::new(rt, &arts, &x, alpha)?;

    // §3.4 rescaling: estimate ||K|| with power iteration ON THE ARTIFACT.
    let t = Timer::start();
    let exec = ExecPolicy::serial(); // PJRT owns device parallelism
    let kappa = spectral_norm(
        &op,
        &NormEstParams { iters: 20, vectors: Some(d), safety: 1.01 },
        &mut rng,
        &exec,
    );
    println!("||K|| estimate via PJRT power iteration: {kappa:.3} ({:.2}s)", t.elapsed_secs());

    // FastEmbed the kernel's top eigenspace: f = I(lambda >= 0.2 ||K||)
    // picks up the per-cluster dominant modes of the near-block-diagonal
    // kernel; cascade b=2 keeps the null band (within-cluster noise
    // modes) suppressed despite the modest order.
    let f = SpectralFn::Step { c: 0.2 * kappa };
    let plan = plan_scaled(&f, kappa, 48, 2, Basis::Legendre);
    let scaled = ScaledOp::new(&op, 1.0 / kappa, 0.0);
    let omega = rademacher_omega(&mut rng, l, d);
    let t = Timer::start();
    let mut mv = 0;
    let mut e_pjrt = omega.clone();
    for _ in 0..plan.b {
        e_pjrt = apply_series(&scaled, &plan.stage, &e_pjrt, &mut mv, &exec);
    }
    println!(
        "kernel-PCA embedding on the AOT path: {} col-matvecs in {:.2}s",
        mv,
        t.elapsed_secs()
    );

    // Native dense oracle: materialize K (the thing the kernel avoids).
    let t = Timer::start();
    let mut kd = Mat::zeros(l, l);
    for i in 0..l {
        for j in 0..l {
            let d2: f64 = x.row(i).iter().zip(x.row(j)).map(|(a, b)| (a - b) * (a - b)).sum();
            kd[(i, j)] = (-d2 / (2.0 * alpha * alpha)).exp();
        }
    }
    let dense = DenseOp(kd);
    let scaled_native = ScaledOp::new(&dense, 1.0 / kappa, 0.0);
    let mut mv2 = 0;
    let mut e_native = omega.clone();
    for _ in 0..plan.b {
        e_native = apply_series(&scaled_native, &plan.stage, &e_native, &mut mv2, &exec);
    }
    println!(
        "native dense oracle: {:.2}s, max |pjrt - native| = {:.2e}",
        t.elapsed_secs(),
        e_pjrt.max_abs_diff(&e_native)
    );
    assert!(e_pjrt.max_abs_diff(&e_native) < 5e-2, "AOT path disagrees with oracle");

    // Downstream: cluster the embedding, score against planted labels.
    let km = kmeans(&e_pjrt, &KmeansParams { k: clusters, ..Default::default() }, &mut rng);
    let score = nmi(&km.assignment, &labels);
    println!("k-means on kernel embedding: NMI vs planted clusters = {score:.3}");
    assert!(score > 0.5, "kernel PCA embedding failed to separate clusters");
    println!("kernel_pca OK");
    Ok(())
}
