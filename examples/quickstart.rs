//! Quickstart: compressive spectral embedding in ~60 lines.
//!
//! Generates a community-structured graph, computes the compressive
//! embedding of its top eigenspace WITHOUT any eigendecomposition, and
//! verifies against the exact (Lanczos) embedding.
//!
//! Run: `cargo run --release --example quickstart`

use cse::eigen::lanczos::{lanczos, LanczosParams};
use cse::embed::{FastEmbed, Params};
use cse::funcs::SpectralFn;
use cse::sparse::{gen, graph};
use cse::util::rng::Rng;
use cse::util::stats;
use cse::util::timer::Timer;

fn main() {
    let mut rng = Rng::new(0);

    // 1. A graph with 20 planted communities (DBLP-analog, small).
    let n = 4000;
    let k = 20;
    let g = gen::sbm_by_degree(&mut rng, n, k, 12.0, 0.6);
    let na = graph::normalized_adjacency(&g.adj);
    println!("graph: n={} nnz={}", na.rows, na.nnz());

    // Exact baseline first (this is the expensive step the algorithm
    // sidesteps); also tells us where the community/bulk spectral gap is.
    let t = Timer::start();
    // The k community eigenvalues are nearly degenerate; single-vector
    // Krylov needs a deep subspace to resolve all copies (ARPACK restarts
    // instead — see eigen::lanczos docs).
    let exact = lanczos(
        &na,
        k + 4,
        &LanczosParams { subspace: Some(8 * k), ..Default::default() },
        &mut rng,
    );
    let c = (exact.values[k - 1] + exact.values[k]) / 2.0; // mid-gap threshold
    let e_exact = exact.spectral_embedding(|x| if x >= c { 1.0 } else { 0.0 });
    println!(
        "lanczos:   {} eigenpairs in {:.2}s (lambda_k={:.3}, gap to {:.3}; c={c:.3})",
        exact.values.len(),
        t.elapsed_secs(),
        exact.values[k - 1],
        exact.values[k]
    );

    // 2. Compressive embedding of the same eigenspace {lambda >= c}:
    //    d = 6 log n dimensions, order-120 Legendre fit, cascade b=2.
    //    No SVD anywhere — just 120 SpMM passes.
    let fe = FastEmbed::new(Params { d: 0, order: 120, cascade: 2, ..Params::default() });
    let t = Timer::start();
    let emb = fe.embed(&na, &SpectralFn::Step { c }, &mut rng);
    println!(
        "fastembed: d={} matvecs={} in {:.2}s",
        emb.e.cols,
        emb.matvecs,
        t.elapsed_secs()
    );

    // 4. Compare pairwise normalized correlations on random pairs.
    let mut devs = Vec::new();
    for _ in 0..2000 {
        let (i, j) = (rng.below(n), rng.below(n));
        if i != j {
            devs.push((e_exact.row_corr(i, j) - emb.e.row_corr(i, j)).abs());
        }
    }
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "correlation deviation: p50={:.3} p95={:.3} (paper Fig 1a: 90% within 0.2 at d=6logn)",
        stats::percentile(&devs, 50.0),
        stats::percentile(&devs, 95.0)
    );
}
