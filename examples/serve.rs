//! Serving demo: build an embedding once, then answer similarity queries
//! from it — the "downstream inference" interface of §1, with the
//! coordinator's batched query service and latency metrics.
//!
//! Run: `cargo run --release --example serve -- [--n 20000] [--queries 5000]`

use cse::coordinator::service::Query;
use cse::coordinator::{Coordinator, EmbedJob, QueryBatch, SimilarityService};
use cse::embed::Params;
use cse::funcs::SpectralFn;
use cse::sparse::{gen, graph};
use cse::util::args::Args;
use cse::util::rng::Rng;
use cse::util::timer::Timer;

fn main() {
    let a = Args::from_env(&[]).unwrap();
    let n = a.usize("n", 20_000).unwrap();
    let nq = a.usize("queries", 5_000).unwrap();
    let workers = a.usize("workers", 2).unwrap();

    let mut rng = Rng::new(a.u64("seed", 0).unwrap());
    let g = gen::sbm_by_degree(&mut rng, n, n / 100, 5.0, 1.0);
    let labels = g.labels.clone().unwrap();
    let na = graph::normalized_adjacency(&g.adj);
    println!("graph: n={n} nnz={}", na.nnz());

    // Build the embedding (the one-time "index build").
    let job = EmbedJob::new(
        Params { d: 0, order: 120, cascade: 2, ..Params::default() },
        SpectralFn::Step { c: 0.8 },
        1,
    );
    let t = Timer::start();
    let res = Coordinator::new(workers).run(&na, &job).expect("embed job failed");
    println!(
        "index build: d={} in {:.1}s ({} matvecs)",
        res.e.cols,
        t.elapsed_secs(),
        res.matvecs
    );

    let service = SimilarityService::new(res.e);

    // Mixed query workload.
    let queries: Vec<Query> = (0..nq)
        .map(|t| {
            if t % 10 == 0 {
                Query::TopK { i: rng.below(n), k: 10 }
            } else {
                Query::Corr { i: rng.below(n), j: rng.below(n) }
            }
        })
        .collect();
    let t = Timer::start();
    let answers = QueryBatch::run(&service, &queries, workers);
    let secs = t.elapsed_secs();
    println!(
        "{} queries in {:.2}s — {:.0} qps, latency p50 {:.1} µs / p99 {:.1} µs (mean {:.1} µs)",
        answers.len(),
        secs,
        answers.len() as f64 / secs,
        service.metrics.query_percentile_us(50.0),
        service.metrics.query_percentile_us(99.0),
        service.metrics.mean_query_us()
    );

    // Qualitative check: top-1 neighbour is usually in the same planted
    // community.
    let mut hits = 0;
    let trials = 300;
    for _ in 0..trials {
        let i = rng.below(n);
        let top = service.top_k(i, 1);
        if let Some(&(j, _)) = top.first() {
            if labels[i] == labels[j] {
                hits += 1;
            }
        }
    }
    println!(
        "top-1 neighbour same-community rate: {:.1}% ({} trials)",
        100.0 * hits as f64 / trials as f64,
        trials
    );
}
