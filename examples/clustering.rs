//! End-to-end driver (EXPERIMENTS.md §E2E): the paper's Amazon graph
//! clustering experiment on the Amazon-analog workload.
//!
//! Pipeline: generate graph → normalized adjacency → compressive
//! embedding via the column-shard coordinator → K-means (25 restarts) →
//! median modularity, compared against the three baselines the paper
//! uses: exact-d eigenvectors, exact-1.5d eigenvectors and randomized
//! SVD — reporting the paper's headline metric (modularity).
//!
//! Run: `cargo run --release --example clustering -- [--n 8000] [--quick]`

use cse::cluster::{kmeans, modularity, nmi, KmeansParams};
use cse::coordinator::{Coordinator, EmbedJob};
use cse::eigen::simult::simultaneous_iteration;
use cse::eigen::rsvd::{rsvd, RsvdParams};
use cse::embed::Params;
use cse::funcs::SpectralFn;
use cse::linalg::Mat;
use cse::par::ExecPolicy;
use cse::sparse::{gen, graph, Csr};
use cse::util::args::Args;
use cse::util::rng::Rng;
use cse::util::stats;
use cse::util::timer::Timer;

fn median_modularity(
    adj: &Csr,
    e: &Mat,
    kk: usize,
    restarts: usize,
    labels: &[usize],
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut mods = Vec::new();
    let mut nmis = Vec::new();
    let exec = ExecPolicy::auto();
    for _ in 0..restarts {
        let km = kmeans(e, &KmeansParams { k: kk, max_iters: 25, tol: 1e-5, exec }, &mut rng);
        mods.push(modularity(adj, &km.assignment));
        nmis.push(nmi(&km.assignment, labels));
    }
    (stats::median(&mods), stats::median(&nmis))
}

fn main() {
    let a = Args::from_env(&["quick"]).unwrap();
    let quick = a.flag("quick");
    let n = a.usize("n", if quick { 3000 } else { 8000 }).unwrap();
    let communities = a.usize("k", if quick { 40 } else { 100 }).unwrap();
    let kk = a.usize("kmeans-k", communities).unwrap();
    let restarts = a.usize("restarts", if quick { 5 } else { 25 }).unwrap();
    let d = a.usize("d", if quick { 24 } else { 48 }).unwrap(); // d < keep: more eigs than dims
    let order = a.usize("order", 160).unwrap();
    let keep = a.usize("keep", communities).unwrap(); // eigenspace captured compressively

    let mut rng = Rng::new(a.u64("seed", 0).unwrap());
    let exec = ExecPolicy::auto(); // every solver runs on all cores
    println!("== Amazon-analog clustering (paper §5, Table-style comparison) ==");
    // Heterogeneous community strengths (see gen::sbm_hetero docs).
    let g = gen::sbm_hetero(&mut rng, n, communities, 5.0, 18.0, 0.6);
    let labels = g.labels.clone().unwrap();
    let na = graph::normalized_adjacency(&g.adj);
    println!("graph: n={n} communities={communities} nnz={}", na.nnz());

    // Ground-truth spectrum (for the threshold): find lambda_keep.
    let t = Timer::start();
    // Block method: the community eigenvalues are near-degenerate, which
    // defeats single-vector Krylov; simultaneous iteration captures the
    // whole subspace.
    let exact = simultaneous_iteration(&na, keep + 8, 100, &mut rng, &exec);
    let t_exact_full = t.elapsed_secs();
    let lam_keep = exact.values[keep - 1];
    println!(
        "exact spectrum: lambda_1={:.4} lambda_{}={:.4} ({:.1}s for {} pairs)",
        exact.values[0],
        keep,
        lam_keep,
        t_exact_full,
        keep + 8
    );

    // --- Row 1: compressive embedding capturing `keep` eigenvectors in d dims.
    let t = Timer::start();
    let job = EmbedJob::new(
        Params { d, order, cascade: 2, exec, ..Params::default() },
        SpectralFn::Step { c: lam_keep - 1e-3 },
        7,
    );
    let res = Coordinator::new(1).run(&na, &job).expect("embed job failed");
    let t_fe = t.elapsed_secs();
    let (q_fe, nmi_fe) = median_modularity(&na, &res.e, kk, restarts, &labels, 1);

    // --- Row 2: exact spectral embedding with d eigenvectors (same K-means dim).
    let t = Timer::start();
    let exact_d = simultaneous_iteration(&na, d, 100, &mut rng, &exec);
    let e_d = exact_d.vectors.clone();
    let t_ed = t.elapsed_secs();
    let (q_ed, nmi_ed) = median_modularity(&na, &e_d, kk, restarts, &labels, 2);

    // --- Row 3: exact with 1.5d eigenvectors (paper's 120 vs 80).
    let t = Timer::start();
    let exact_15 = simultaneous_iteration(&na, 3 * d / 2, 100, &mut rng, &exec);
    let t_e15 = t.elapsed_secs();
    let (q_e15, nmi_e15) = median_modularity(&na, &exact_15.vectors, kk, restarts, &labels, 3);

    // --- Row 4: randomized SVD with d vectors (q=5, l=10 per the paper).
    let t = Timer::start();
    let rs = rsvd(&na, d, &RsvdParams { exec, ..Default::default() }, &mut rng);
    let t_rs = t.elapsed_secs();
    let (q_rs, nmi_rs) = median_modularity(&na, &rs.vectors, kk, restarts, &labels, 4);

    println!("\n{:<38} {:>9} {:>11} {:>8}", "method", "time", "modularity", "NMI");
    let row = |name: &str, t: f64, q: f64, m: f64| {
        println!("{name:<38} {t:>8.1}s {q:>11.4} {m:>8.4}");
    };
    row(&format!("FastEmbed (d={d}, captures {keep} eigs)"), t_fe, q_fe, nmi_fe);
    row(&format!("exact partial SVD ({d} eigs)"), t_ed, q_ed, nmi_ed);
    row(&format!("exact partial SVD ({} eigs)", 3 * d / 2), t_e15, q_e15, nmi_e15);
    row(&format!("randomized SVD ({d} eigs, q=5, l=10)"), t_rs, q_rs, nmi_rs);
    println!(
        "\npaper's shape: FastEmbed >= exact(1.5d) > exact(d) > RSVD on modularity, \
         at a fraction of exact cost"
    );
}
